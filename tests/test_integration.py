"""End-to-end integration: distributed train step on a real mesh (8 CPU
devices), FlexLink-vs-NCCL backend equivalence, learning on the synthetic
corpus, checkpoint roundtrip, serving engine behaviour."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.communicator import CommConfig, comm_destroy_all
from repro.data.pipeline import SyntheticCorpus, DataConfig, make_batches
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch import shapes as SH
from repro.launch.mesh import make_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)
from repro.models import init_params, single_device_ctx
from repro.models.transformer import DecodeConfig
from repro.optim.adamw import AdamWConfig, init_state
from repro.serving.engine import ServeConfig, ServeEngine

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 CPU devices")
KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _fresh():
    comm_destroy_all()
    yield
    comm_destroy_all()


def _train_setup(arch="glm4-9b", backend="flexlink", mesh_dims=(2, 4)):
    cfg = get_config(arch).reduced()
    mesh = make_mesh(mesh_dims, ("data", "model"))
    shape = SH.InputShape("t", "train", 32, 4)
    comm = CommConfig(backend=backend, profile="tpu_v5e")
    step, ctx = build_train_step(cfg, mesh, comm=comm,
                                 opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                                 total_steps=20),
                                 shape=shape)
    params = init_params(KEY, cfg)
    opt_state = init_state(params)
    batches = make_batches(cfg, seq_len=32, batch_per_shard=4, seed=7)
    return cfg, mesh, step, params, opt_state, batches


@needs8
def test_distributed_train_step_runs_and_learns():
    cfg, mesh, step, params, opt_state, batches = _train_setup()
    losses = []
    with mesh:
        for i in range(12):
            params, opt_state, m = step(params, opt_state,
                                        {k: jnp.asarray(v)
                                         for k, v in next(batches).items()})
            losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses  # learning on synthetic corpus


@needs8
def test_flexlink_equals_nccl_backend():
    """The multi-path backend must be numerically equivalent to the
    single-path (NCCL) backend — the paper's lossless claim end-to-end."""
    out = {}
    for backend in ("nccl", "flexlink"):
        comm_destroy_all()
        cfg, mesh, step, params, opt_state, batches = _train_setup(
            backend=backend)
        with mesh:
            for i in range(3):
                params, opt_state, m = step(
                    params, opt_state,
                    {k: jnp.asarray(v) for k, v in next(batches).items()})
        out[backend] = float(m["loss"])
    assert abs(out["flexlink"] - out["nccl"]) < 5e-3, out


@needs8
def test_moe_ep_a2a_distributed():
    """kimi-style ep_a2a MoE: experts sharded over data, a2a dispatch."""
    cfg, mesh, step, params, opt_state, batches = _train_setup(
        arch="kimi-k2-1t-a32b")
    with mesh:
        params, opt_state, m = step(params, opt_state,
                                    {k: jnp.asarray(v)
                                     for k, v in next(batches).items()})
    assert np.isfinite(float(m["loss"]))


@needs8
def test_distributed_serve_step():
    cfg = get_config("glm4-9b").reduced()
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = SH.InputShape("d", "decode", 64, 8)
    step, ctx, dcfg = build_serve_step(cfg, mesh, shape)
    with mesh:
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            SH.input_specs(cfg, shape, tp=4, dp=2)["cache"])
        params = init_params(KEY, cfg)
        tok = jnp.zeros((8, 1), jnp.int32)
        logits, cache = step(params, cache, tok, jnp.int32(0))
        logits2, _ = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (8, cfg.vocab)
    assert not bool(jnp.isnan(jnp.asarray(logits)).any())


@needs8
def test_seq_sharded_decode_matches_local():
    """Sequence-sharded decode (the long_500k mechanism) must produce the
    same logits as unsharded decode."""
    cfg = get_config("glm4-9b").reduced()
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab)

    # local reference
    from repro.models.transformer import decode_step, init_cache
    ctx0 = single_device_ctx()
    dcfg0 = DecodeConfig(cache_len_local=16, seq_shard=None)
    cache = init_cache(cfg, ctx0, dcfg0, 2)
    for t in range(10):
        ref, cache = decode_step(params, cache, toks[:, t:t + 1],
                                 jnp.int32(t), cfg, ctx0, dcfg0)

    # sharded: mesh (2, 4) — cache seq sharded over model=4 (tp must
    # divide the reduced config's 4 Q heads)
    mesh = make_mesh((2, 4), ("data", "model"))
    shape = SH.InputShape("d", "decode", 16, 2)
    step, ctx, dcfg = build_serve_step(cfg, mesh, shape)
    with mesh:
        cache_s = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            SH.input_specs(cfg, shape, tp=4, dp=2)["cache"])
        for t in range(10):
            got, cache_s = step(params, cache_s, toks[:, t:t + 1],
                                jnp.int32(t))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_checkpoint_roundtrip():
    cfg = get_config("glm4-9b").reduced()
    params = init_params(KEY, cfg)
    opt_state = init_state(params)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        ck.save(3, params, opt_state, extra={"note": "x"})
        ck.save(7, params, opt_state)
        p2, o2, meta = ck.restore(params, opt_state)
        assert meta["step"] == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt_state), jax.tree.leaves(o2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # retention
        ck.save(9, params)
        assert ck.all_steps() == [7, 9]


def test_corpus_is_learnable_and_deterministic():
    c1 = SyntheticCorpus(DataConfig(vocab=64, seq_len=16, batch_per_shard=2,
                                    seed=5))
    c2 = SyntheticCorpus(DataConfig(vocab=64, seq_len=16, batch_per_shard=2,
                                    seed=5))
    b1, b2 = c1.batch(), c2.batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards differ
    c3 = SyntheticCorpus(DataConfig(vocab=64, seq_len=16, batch_per_shard=2,
                                    seed=5), shard=1, n_shards=2)
    assert not np.array_equal(c3.batch()["tokens"], b1["tokens"])


def test_serving_engine_greedy_deterministic():
    cfg = get_config("glm4-9b").reduced()
    params = init_params(KEY, cfg)
    outs = []
    for _ in range(2):
        eng = ServeEngine(params, cfg, single_device_ctx(),
                          ServeConfig(slots=2, cache_len=48))
        eng.submit([5, 6, 7], max_new=6)
        eng.submit([9, 10, 11, 12], max_new=6)
        eng.run_until_drained()
        outs.append(eng.finished())
    assert outs[0] == outs[1]
    assert all(len(v) == 6 for v in outs[0].values())


def test_serving_engine_waves_retire_and_refill():
    cfg = get_config("glm4-9b").reduced()
    params = init_params(KEY, cfg)
    eng = ServeEngine(params, cfg, single_device_ctx(),
                      ServeConfig(slots=2, cache_len=48))
    for i in range(5):
        eng.submit([1 + i, 2 + i], max_new=4)
    eng.run_until_drained()
    assert len(eng.finished()) == 5
