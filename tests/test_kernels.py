"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.chunk_accumulate import LANE, SUBLANE, chunk_accumulate_2d
from repro.kernels.payload_partition import BLOCK, extract_segment, \
    merge_segments


# ---------------------------------------------------------------------------
# chunk_accumulate
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
@pytest.mark.parametrize("shape", [(8, 128), (16, 256), (264, 128),
                                   (1024, 384)])
def test_chunk_accumulate_2d_matches_ref(dtype, shape):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    if dtype == jnp.int32:
        a = jax.random.randint(k1, shape, -100, 100, dtype=jnp.int32)
        b = jax.random.randint(k2, shape, -100, 100, dtype=jnp.int32)
    else:
        a = jax.random.normal(k1, shape, dtype=jnp.float32).astype(dtype)
        b = jax.random.normal(k2, shape, dtype=jnp.float32).astype(dtype)
    got = chunk_accumulate_2d(a, b, interpret=True)
    want = ref.chunk_accumulate_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


def test_accumulate_fp32_path_beats_bf16_accumulation():
    """The acc_dtype=fp32 design point: adding a tiny value to a large one
    in bf16 loses it; the kernel's fp32 accumulate keeps it (then rounds
    once on store)."""
    a = jnp.full((8, 128), 256.0, dtype=jnp.bfloat16)
    b = jnp.full((8, 128), 1.0, dtype=jnp.bfloat16)
    got = chunk_accumulate_2d(a, b, acc_dtype=jnp.float32, interpret=True)
    # 257 rounds to 256 in bf16 either way, but with acc fp32 the rounding
    # happens once; check exact agreement with the oracle.
    want = ref.chunk_accumulate_ref(a, b, acc_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@given(n=st.integers(1, 5000),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=25, deadline=None)
def test_property_accumulate_arbitrary_shapes(n, dtype):
    """ops.accumulate pads any payload to tiles and matches a + b."""
    a = (jnp.arange(n, dtype=jnp.float32) * 0.37).astype(dtype)
    b = (jnp.arange(n, dtype=jnp.float32) * -0.11).astype(dtype)
    got = ops.accumulate(a, b)
    want = ref.chunk_accumulate_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64))


def test_accumulate_is_ring_pluggable():
    """The ops.ring_accumulate_fn closure drops into ring_all_reduce."""
    import jax
    from jax import lax
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.compat import shard_map
    from repro.core.collectives import ring_all_reduce
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 devices")
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("x",))
    x = jnp.arange(8 * 16, dtype=jnp.float32) * 0.25

    def ring(xs):
        return ring_all_reduce(xs, "x", accumulate=ops.ring_accumulate_fn())

    f = shard_map(ring, mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
                  check_vma=False)
    r = shard_map(lambda xs: lax.psum(xs, "x"), mesh=mesh,
                  in_specs=(P("x"),), out_specs=P("x"), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x)),
                               np.asarray(jax.jit(r)(x)), rtol=1e-6)


# ---------------------------------------------------------------------------
# payload split / merge
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n_blocks,start", [(1, 0), (2, 1), (3, 5)])
def test_extract_segment_matches_ref(dtype, n_blocks, start):
    total_blocks = 8
    x = (jnp.arange(total_blocks * BLOCK, dtype=jnp.float32) * 0.5).astype(dtype)
    got = extract_segment(x, start, n_blocks, interpret=True)
    want = ref.extract_segment_ref(x, start, n_blocks, block=BLOCK)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@given(sizes=st.lists(st.integers(1, 4), min_size=1, max_size=4))
@settings(max_examples=10, deadline=None)
def test_property_split_merge_roundtrip(sizes):
    """extract_segment per route + merge_segments == identity."""
    total = sum(sizes)
    x = jnp.arange(total * BLOCK, dtype=jnp.float32)
    segs, off = [], 0
    for s in sizes:
        segs.append(extract_segment(x, off, s, interpret=True))
        off += s
    back = merge_segments(segs, block=BLOCK)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    want = ref.merge_segments_ref(segs)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(want))
