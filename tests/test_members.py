"""Per-instance link fabric (DESIGN.md §10).

Two contracts anchor the refactor:

* PARITY — with uniform healthy members, every plan, ``plan_signature()``
  and simulated timing is BIT-identical to the class-level (memberless)
  model: the member dimension must cost nothing until instances diverge.
* DRAIN — with one NIC rail degraded, Stage 2 converges to a plan where
  only that member's share is reduced; its siblings stay within one
  member-grid unit of their healthy shares and the CLASS share vector
  does not move (the hold rule).
"""

import dataclasses
import json

import pytest

from _hyp import given, settings, st

from repro.cluster.topology import (degrade_cluster, make_cluster,
                                    make_nic_tier)
from repro.control import (DegradedTimingSource, MEMBER_BASE,
                           MeasuredTimingSource, SlotController,
                           TuningProfile)
from repro.core.communicator import CommConfig, FlexCommunicator
from repro.core.links import (LinkKind, LinkMember, LinkSpec, PROFILES,
                              degrade_profile, degraded_profile_name,
                              idle_bw_opportunity, parse_degrade,
                              register_profile, split_by_health)
from repro.core.routing import build_plan, canonical_member_layout
from repro.core.simulator import MiB, PathTimingModel
from repro.core.topology import Collective
from repro.core.tuner import SHARE_GRID, initial_tune, measure_fn

AR = Collective.ALL_REDUCE
AG = Collective.ALL_GATHER


def _membered(profile, link_name, n):
    """A copy of ``profile`` whose ``link_name`` carries n uniform healthy
    members — the parity construction.  The name is kept: the h800
    primary calibration is keyed on it, and these copies are fed straight
    to PathTimingModel, never registered."""
    links = tuple(
        l.with_members([f"{l.name}.{i}" for i in range(n)])
        if l.name == link_name else l for l in profile.links)
    return dataclasses.replace(profile, links=links)


def _nic8(name="members_h800_rail8"):
    return make_cluster("h800", 2, nics_per_node=8, nic_gbit=400.0,
                        name=name)


# ---------------------------------------------------------------------------
# parity: uniform healthy members == class-level model, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("base,link", [("h800", "rdma"), ("tpu_v5e", "dcn")])
@pytest.mark.parametrize("n_members", [2, 4, 8])
def test_parity_intra_timing_bitwise(base, link, n_members):
    prof = PROFILES[base]
    memb = _membered(prof, link, n_members)
    m0, m1 = PathTimingModel(prof), PathTimingModel(memb)
    paths = [l.name for l in prof.links]
    for op in (AR, AG):
        for mib in (1, 32, 256):
            for shares in ({p: 1.0 / len(paths) for p in paths},
                           {paths[0]: 0.7, link: 0.3}):
                a = m0.measure(op, 8, mib * MiB, shares)
                b = m1.measure(op, 8, mib * MiB, shares)
                assert a == b, (op, mib, shares)
                assert (m0.total_time(op, 8, mib * MiB, shares)
                        == m1.total_time(op, 8, mib * MiB, shares))


def test_parity_inter_tier_timing_and_stage1_bitwise():
    """The NIC tier ships WITH per-rail members now; a memberless clone is
    the pre-refactor model, and healthy they must be indistinguishable."""
    nic = _nic8().nic_tier
    flat = dataclasses.replace(
        nic, name=nic.name + ":flat",
        links=tuple(dataclasses.replace(l, members=()) for l in nic.links))
    m_memb, m_flat = PathTimingModel(nic), PathTimingModel(flat)
    paths = [l.name for l in nic.links]
    for op in (AR, AG):
        for mib in (4, 64, 256):
            res_m = initial_tune(paths, "rail",
                                 measure_fn(m_memb, op, 2, mib * MiB))
            res_f = initial_tune(paths, "rail",
                                 measure_fn(m_flat, op, 2, mib * MiB))
            assert res_m.shares == res_f.shares
            assert res_m.iterations == res_f.iterations
            fr = res_m.fractions()
            assert (m_memb.measure(op, 2, mib * MiB, fr)
                    == m_flat.measure(op, 2, mib * MiB, fr))


def test_parity_plan_signature_bitwise():
    """Communicator-level: tuned plans + signatures of the membered NIC
    tier equal the memberless clone's, slot for slot."""
    nic = _nic8().nic_tier
    flat = register_profile(dataclasses.replace(
        nic, name=nic.name + ":flatsig",
        links=tuple(dataclasses.replace(l, members=()) for l in nic.links)))
    c_m = FlexCommunicator("node", 2, CommConfig(profile=nic.name))
    c_f = FlexCommunicator("node", 2, CommConfig(profile=flat.name))
    for comm in (c_m, c_f):
        for op in (AR, AG):
            for nbytes in (1 << 20, 64 << 20, 256 << 20):
                comm._bucket_plan(op, nbytes)
    assert c_m.plan_signature() == c_f.plan_signature()
    for op in (AR, AG):
        pm = c_m._bucket_plan(op, 64 << 20)
        assert pm.member_layout == ()
        assert pm == c_f._bucket_plan(op, 64 << 20)


def test_parity_with_noise_same_rng_stream():
    """The uniform fast path must not consume extra rng draws: noisy
    timings match the memberless model draw for draw."""
    prof = PROFILES["h800"]
    memb = _membered(prof, "pcie", 4)
    m0 = PathTimingModel(prof, noise=0.05, seed=7)
    m1 = PathTimingModel(memb, noise=0.05, seed=7)
    shares = {"nvlink": 0.6, "pcie": 0.25, "rdma": 0.15}
    for _ in range(20):
        assert (m0.measure(AR, 8, 64 * MiB, shares)
                == m1.measure(AR, 8, 64 * MiB, shares))


@settings(max_examples=40, deadline=None)
@given(n_members=st.integers(2, 8),
       units=st.lists(st.integers(0, 40), min_size=3, max_size=3),
       op=st.sampled_from([AR, AG]))
def test_uniform_member_plans_match_class_plans(n_members, units, op):
    """Property: ANY share vector builds the same plan with a uniform
    member layout as with none — signature for signature."""
    shares = {"primary": units[0], "staged": units[1], "ortho": units[2]}
    if sum(units) == 0:
        shares = None
    layout = {"staged": tuple((f"m{i}", 5) for i in range(n_members))}
    a = build_plan(op, "x", shares, "y")
    b = build_plan(op, "x", shares, "y", member_layout=layout)
    assert a == b
    assert b.member_layout == ()


def test_canonical_member_layout_rules():
    units = {"primary": 10, "staged": 6}
    # gcd-normalization: scaled vectors are the same identity
    a = canonical_member_layout(
        {"primary": (("r0", 8), ("r1", 2))}, units)
    b = canonical_member_layout(
        {"primary": (("r0", 16), ("r1", 4))}, units)
    assert a == b == (("primary", (("r0", 4), ("r1", 1))),)
    # a zero-weight member is a live drain, not a shorter uniform vector
    z = canonical_member_layout(
        {"primary": (("r0", 3), ("r1", 3), ("r2", 0))}, units)
    assert z == (("primary", (("r0", 1), ("r1", 1), ("r2", 0))),)
    # classes carrying no payload drop out
    assert canonical_member_layout(
        {"ortho": (("r0", 2), ("r1", 1))}, units) == ()


# ---------------------------------------------------------------------------
# drain: one degraded rail, Stage 2, the acceptance trajectory
# ---------------------------------------------------------------------------

def _degraded_nic8():
    cl = _nic8("members_h800_rail8_d")
    return cl.nic_tier, degrade_cluster(cl, "rail3=0.25").nic_tier


def test_stage2_drains_only_the_sick_member():
    healthy, degraded = _degraded_nic8()
    mh = PathTimingModel(healthy)
    md = PathTimingModel(degraded)
    res = initial_tune(["rail", "xrail", "host_tcp"], "rail",
                       measure_fn(mh, AR, 2, 256 * MiB))
    uniform = {"rail": {m.name: MEMBER_BASE for m in
                        degraded.link("rail").members}}
    sc = SlotController.warm_start(
        AR, 256 << 20, dict(res.shares), "rail",
        members=degraded.multi_member_links(), member_weights=uniform)
    for _ in range(400):
        t = md.measure(AR, 2, 256 * MiB, sc.fractions(),
                       member_weights=sc.member_weights())
        sc.report(t)
    weights = sc.member_weights()["rail"]
    rail3 = weights.pop("rail3")
    siblings = list(weights.values())
    # only the sick member drained; siblings within 1 grid unit of their
    # healthy (equal) share
    assert rail3 < min(siblings)
    assert all(abs(w - MEMBER_BASE) <= 1 for w in siblings)
    # the hold rule kept the CLASS share vector untouched
    assert sc.shares == res.shares
    assert len(sc.balancer.adjustments) == 0
    assert sum(len(b.adjustments) for b in sc.member_balancers.values()) > 0


def test_drain_rekeys_plan_and_signature_via_communicator():
    """End to end through record_call: a warm-started slot with uniform
    weights on the degraded fabric drains, the plan's member_layout goes
    non-uniform, and observe_executed_step reports the re-key."""
    _, degraded = _degraded_nic8()
    register_profile(degraded)
    comm = FlexCommunicator("node", 2, CommConfig(profile=degraded.name))
    sc = comm.slot(AR, 256 << 20)
    # reset the health-aware start to the uniform (healthy-believed) split
    for bal in sc.member_balancers.values():
        for k in bal.shares:
            bal.shares[k] = MEMBER_BASE
    plan0 = comm._bucket_plan(AR, 256 << 20)
    assert plan0.member_layout == ()
    sig0 = comm.plan_signature()
    moved = False
    for _ in range(400):
        if comm.observe_executed_step():
            moved = True
        comm._default_recorder.record(AR, 256 << 20)
    assert moved
    plan1 = comm._bucket_plan(AR, 256 << 20)
    assert plan1.member_layout != ()
    assert dict(plan1.member_layout)["primary"] is not None
    assert comm.plan_signature() != sig0
    # the drain re-keys the plan ONCE at its settled endpoint (plan
    # weights are frozen while the intra-class gap is live), not once per
    # unit move — re-jitting byte-identical HLO ~6 times per episode
    assert 1 <= comm.plan_cache.stats.retraces <= 2
    weights = sc.member_weights()["rail"]
    assert weights["rail3"] < min(v for k, v in weights.items()
                                  if k != "rail3")
    rep = comm.report()
    blk = rep[f"{AR.value}@{256 << 20}"]
    assert blk["members"]["rail"]["health"]["rail3"] == 0.25
    assert rep["rollup"]["inter"]["drained_members"] >= 1


def test_stage1_level_drain_on_degraded_profile():
    """A cold tune on the degraded fabric starts the sick member
    pre-drained (health-proportional weights) — what the dryrun CI smoke
    observes without running Stage 2."""
    _, degraded = _degraded_nic8()
    register_profile(degraded)
    comm = FlexCommunicator("node", 2, CommConfig(profile=degraded.name))
    sc = comm.slot(AG, 64 << 20)
    w = sc.member_weights()["rail"]
    assert w["rail3"] < min(v for k, v in w.items() if k != "rail3")
    assert all(abs(v - MEMBER_BASE) <= 1 for k, v in w.items()
               if k != "rail3")
    assert comm._bucket_plan(AG, 64 << 20).member_layout != ()


# ---------------------------------------------------------------------------
# register_profile contracts under the member model
# ---------------------------------------------------------------------------

def test_register_synthesized_rail_tier_idempotent():
    a = make_nic_tier(PROFILES["h800"], nics_per_node=8, nic_gbit=400.0)
    b = make_nic_tier(PROFILES["h800"], nics_per_node=8, nic_gbit=400.0)
    assert a == b
    r1 = register_profile(a)
    r2 = register_profile(b)
    assert r1 is r2
    assert len(r1.link("rail").members) == 8


def test_register_conflicting_member_layout_raises():
    a = make_nic_tier(PROFILES["a800"], nics_per_node=4, nic_gbit=400.0)
    register_profile(a)
    conflict = dataclasses.replace(
        a, links=(a.links[0].degraded("rail1", 0.5),) + a.links[1:])
    with pytest.raises(ValueError, match="different parameters"):
        register_profile(conflict)


def test_register_rejects_colliding_member_names():
    nic = _nic8("members_collide").nic_tier
    # a member named after a sibling link cross-wires timing dicts
    bad_member = dataclasses.replace(
        nic, name="members_collide_a",
        links=(nic.links[0].with_members(
            ["rail0", "rail1", "rail2", "xrail",
             "rail4", "rail5", "rail6", "rail7"]),) + nic.links[1:])
    with pytest.raises(ValueError, match="collides with a link name"):
        register_profile(bad_member)
    # two links sharing a member name is ambiguous instance addressing
    dup = dataclasses.replace(
        nic, name="members_collide_b",
        links=(nic.links[0],
               nic.links[1].with_members(["rail0", "x1"]),
               nic.links[2]))
    with pytest.raises(ValueError, match="appears in links"):
        register_profile(dup)
    # the allowed shadowing: a degraded memberless link materializes its
    # single self-named member
    ok = degrade_profile(PROFILES["gb300"], "rdma=0.5", register=False)
    register_profile(ok)
    # a duplicate WITHIN one link conflates two physical instances (and
    # silently loses split_by_health units) — rejected too
    same = dataclasses.replace(
        nic, name="members_collide_c",
        links=(nic.links[0].with_members(
            ["rail0", "rail0", "rail2", "rail3",
             "rail4", "rail5", "rail6", "rail7"]),) + nic.links[1:])
    with pytest.raises(ValueError, match="twice"):
        register_profile(same)


def test_dead_member_prices_as_inf_not_crash():
    """factor=0 is a legal spec (a dead rail): the analytics must price
    it as unusable, not raise ZeroDivisionError."""
    from repro.cluster import ClusterTimingModel
    cl = _nic8("members_h800_rail8_z")
    dead_rail = degrade_cluster(cl, "rail3=0")
    model = ClusterTimingModel(dead_rail, 8)
    assert model.flat_time(AR, MiB) == float("inf")
    assert model.algbw_GBps(AR, MiB, schedule="flat") == 0.0
    # hierarchical still works: the NIC tier routes around the dead rail
    assert model.hierarchical_time(AR, MiB) < float("inf")
    # a dead PRIMARY makes the idle-BW ratio infinite, not a crash
    d = degrade_profile(PROFILES["h800"], "nvlink=0", register=False)
    assert idle_bw_opportunity(d) == float("inf")


def test_degraded_profile_names_are_deterministic_and_distinct():
    nic = _nic8("members_h800_rail8_n").nic_tier
    d1 = degrade_profile(nic, "rail3=0.25")
    d2 = degrade_profile(nic, "rail3=0.25")
    assert d1 is d2                       # registered once, resolved again
    assert d1.name == degraded_profile_name(nic.name, "rail", "rail3", 0.25)
    assert d1.name != nic.name
    with pytest.raises(ValueError, match="different parameters"):
        register_profile(dataclasses.replace(nic, name=d1.name))


# ---------------------------------------------------------------------------
# idle_bw_opportunity — first direct unit tests (+ degraded members)
# ---------------------------------------------------------------------------

def test_idle_bw_paper_rows():
    # Table-1 reproduction, via the hardware DB (benchmarks/table1_idle_bw)
    paper = {"h800": 32, "h100": 14, "a800": 16, "gb200": 22, "gb300": 33}
    for name, pct in paper.items():
        got = idle_bw_opportunity(PROFILES[name]) * 100
        assert abs(got - pct) <= 1.5, (name, got, pct)


def test_idle_bw_gb300_no_contention_row():
    """GB300 decouples the IO paths: the opportunity is the plain sum of
    secondary raw bandwidths over NVLink — no PCIe ceiling involved."""
    p = PROFILES["gb300"]
    assert p.pcie_switch_ceiling_GBps is None
    assert not any(l.shares_pcie_switch for l in p.secondary)
    expect = sum(l.raw_GBps for l in p.secondary) / p.primary.raw_GBps
    assert idle_bw_opportunity(p) == pytest.approx(expect)
    # degrading a secondary member shrinks the opportunity proportionally
    d = degrade_profile(p, "rdma=0.5", register=False)
    lost = 0.5 * p.link("rdma").raw_GBps / p.primary.raw_GBps
    assert idle_bw_opportunity(d) == pytest.approx(expect - lost)


def test_idle_bw_degraded_member_shrinks_opportunity():
    """A degraded SECONDARY member shrinks the reported opportunity by
    exactly its lost raw-bandwidth slice (uncontended link, so no ceiling
    masks it); a degraded PRIMARY member shrinks the denominator, raising
    the ratio — both directions follow from health-scaling the raws."""
    from repro.core.links import NodeProfile
    prof = NodeProfile(name="idle_member_test", links=(
        LinkSpec("nv", LinkKind.NVLINK, raw_GBps=400.0,
                 effective_GBps=139.0, step_latency_us=4.0),
        LinkSpec("nic", LinkKind.RDMA, raw_GBps=100.0,
                 effective_GBps=40.0, step_latency_us=10.0).with_members(
                     ["nic0", "nic1", "nic2", "nic3"]),
    ))
    base = idle_bw_opportunity(prof)
    assert base == pytest.approx(100.0 / 400.0)
    d = dataclasses.replace(
        prof, links=(prof.links[0],
                     prof.links[1].degraded("nic3", 0.25)))
    # nic3's lost 3/4 of its 25 GB/s slice: 100 -> 81.25 over 400
    assert idle_bw_opportunity(d) == pytest.approx(81.25 / 400.0)
    # primary-member degradation shrinks the denominator instead
    nic = _nic8("members_h800_rail8_i").nic_tier
    dp = dataclasses.replace(
        nic, links=(nic.links[0].degraded("rail3", 0.25),) + nic.links[1:])
    assert dp.link("rail").health_factor == pytest.approx((7 + 0.25) / 8)
    assert idle_bw_opportunity(dp) > idle_bw_opportunity(nic)


def test_split_by_health_exact_and_deterministic():
    mems = tuple(LinkMember(f"r{i}") for i in range(8))
    assert split_by_health(mems, 64) == {f"r{i}": 8 for i in range(8)}
    degraded = tuple(
        dataclasses.replace(m, health=0.25 if m.name == "r3" else 1.0)
        for m in mems)
    w = split_by_health(degraded, 64)
    assert sum(w.values()) == 64
    assert w["r3"] < min(v for k, v in w.items() if k != "r3")


# ---------------------------------------------------------------------------
# TuningProfile: per-instance entries round-trip
# ---------------------------------------------------------------------------

def test_tuning_profile_member_roundtrip(tmp_path):
    path = str(tmp_path / "t.json")
    prof = TuningProfile(path)
    members = {"rail": {"rail0": 9, "rail1": 9, "rail2": 9, "rail3": 2,
                        "rail4": 9, "rail5": 9, "rail6": 9, "rail7": 8}}
    prof.record("p", "ring", AR, 2, 1 << 20, SHARE_GRID,
                {"rail": 60, "xrail": 40}, members=members)
    prof.record("p", "ring", AG, 2, 1 << 20, SHARE_GRID,
                {"rail": 70, "xrail": 30})          # member-less entry
    prof.save()
    back = TuningProfile.load(path)
    assert back.lookup_members("p", "ring", AR, 2, 1 << 20,
                               SHARE_GRID) == members
    assert back.lookup_members("p", "ring", AG, 2, 1 << 20,
                               SHARE_GRID) is None
    # corrupt members block degrades to None, not a crash
    with open(path) as f:
        doc = json.load(f)
    ar_entry, = [e for e in doc["entries"] if e["op"] == AR.value]
    ar_entry["members"] = "garbage"
    with open(path, "w") as f:
        json.dump(doc, f)
    again = TuningProfile.load(path)
    assert again.lookup_members("p", "ring", AR, 2, 1 << 20,
                                SHARE_GRID) is None


def test_warm_start_restores_saved_member_weights():
    drained = {"rail0": 9, "rail1": 9, "rail2": 9, "rail3": 2,
               "rail4": 9, "rail5": 9, "rail6": 9, "rail7": 8}
    nic = _nic8("members_h800_rail8_w").nic_tier
    sc = SlotController.warm_start(
        AR, 1 << 20, {"rail": 60, "xrail": 40, "host_tcp": 0}, "rail",
        members=nic.multi_member_links(),
        member_weights={"rail": drained})
    assert sc.member_weights()["rail"] == drained
    # mismatched member names fall back to the health split
    sc2 = SlotController.warm_start(
        AR, 1 << 20, {"rail": 60, "xrail": 40, "host_tcp": 0}, "rail",
        members=nic.multi_member_links(),
        member_weights={"rail": {"bogus": 64}})
    assert sc2.member_weights()["rail"] == {
        f"rail{i}": MEMBER_BASE for i in range(8)}


# ---------------------------------------------------------------------------
# degrade spec parsing + cluster resolution
# ---------------------------------------------------------------------------

def test_parse_degrade_forms():
    assert parse_degrade("rail3=0.25") == ("rail3", None, 0.25)
    assert parse_degrade("rail:rail3=0.25") == ("rail", "rail3", 0.25)
    assert parse_degrade("pcie=0.5") == ("pcie", None, 0.5)
    for bad in ("rail3", "=0.5", "a=b", "a=-1", ":m=0.5", "l:=0.5"):
        with pytest.raises(ValueError):
            parse_degrade(bad)


def test_degrade_cluster_targets_the_owning_tier():
    cl = _nic8("members_h800_rail8_c")
    d_rail = degrade_cluster(cl, "rail3=0.25")
    assert d_rail.node == cl.node
    assert d_rail.nic_tier.link("rail").member("rail3").health == 0.25
    assert "!rail:rail3=0.25" in d_rail.nic_tier.name
    d_pcie = degrade_cluster(cl, "pcie=0.5")
    assert d_pcie.nic_tier == cl.nic_tier
    assert d_pcie.node.link("pcie").health_factor == 0.5
    with pytest.raises(KeyError):
        degrade_cluster(cl, "nosuch=0.5")


# ---------------------------------------------------------------------------
# DegradedTimingSource — measured-mode fault overlay
# ---------------------------------------------------------------------------

def test_degraded_timing_source_overlays_member_entries():
    _, degraded = _degraded_nic8()
    model = PathTimingModel(degraded)
    src = DegradedTimingSource(MeasuredTimingSource(model))
    assert src.kind == "measured"
    fr = {"rail": 0.6, "xrail": 0.4, "host_tcp": 0.0}
    weights = {"rail": {f"rail{i}": MEMBER_BASE for i in range(8)}}
    t = src.timings_for(AR, 2, 64 << 20, fr, bucket=64 << 20,
                        member_weights=weights)
    # class entries from the measured source, member entries overlaid
    assert set(fr) <= set(t)
    assert {f"rail{i}" for i in range(8)} <= set(t)
    assert t["rail3"] > t["rail0"]        # the sick rail reads slow
    assert src.report()["degraded_overlay"] is True


def test_communicator_wraps_measured_source_on_degraded_profile():
    _, degraded = _degraded_nic8()
    register_profile(degraded)
    c = FlexCommunicator("node", 2, CommConfig(profile=degraded.name,
                                               timing="measured"))
    assert isinstance(c.timing, DegradedTimingSource)
    assert c.timing.kind == "measured"
    healthy = _nic8("members_h800_rail8_hm").nic_tier
    register_profile(healthy)
    c2 = FlexCommunicator("node", 2, CommConfig(profile=healthy.name,
                                                timing="measured"))
    assert isinstance(c2.timing, MeasuredTimingSource)
