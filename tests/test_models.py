"""Model-engine tests: per-family forward, decode parity, SSD equivalence,
MoE dispatch properties, vocab-parallel loss vs dense reference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models import (ArchConfig, DecodeConfig, MoEConfig, SSMConfig,
                          HybridConfig, EncDecConfig, VLMConfig,
                          decode_step, forward, init_cache, init_params,
                          lm_loss, single_device_ctx)
from repro.models.transformer import lm_logits_local, vocab_parallel_xent
from repro.models import layers as L

CTX = single_device_ctx()
KEY = jax.random.PRNGKey(0)


def dense_cfg(**kw):
    d = dict(name="dense-t", family="dense", n_layers=2, d_model=64,
             n_heads=4, n_kv_heads=2, d_ff=128, vocab=97,
             param_dtype="float32")
    d.update(kw)
    return ArchConfig(**d)


FAMILY_CFGS = {
    "dense": dense_cfg(),
    "moe": dense_cfg(name="moe-t", family="moe",
                     moe=MoEConfig(n_experts=4, top_k=2, n_dense_prefix=1,
                                   impl="tp")),
    "ssm": dense_cfg(name="ssm-t", family="ssm", n_heads=0, n_kv_heads=0,
                     d_ff=0, ssm=SSMConfig(d_state=16, head_dim=16, chunk=8)),
    "hybrid": dense_cfg(name="hyb-t", family="hybrid", n_layers=3,
                        ssm=SSMConfig(d_state=16, head_dim=16, chunk=8),
                        hybrid=HybridConfig(attn_every=2)),
    "encdec": dense_cfg(name="enc-t", family="encdec", n_kv_heads=4,
                        encdec=EncDecConfig(n_enc_layers=2, n_frames=8)),
    "vlm": dense_cfg(name="vlm-t", family="vlm",
                     vlm=VLMConfig(n_vis_tokens=4)),
}


def make_batch(cfg, b=2, s=16):
    k1, k2 = jax.random.split(KEY)
    batch = {"tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab),
             "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["vis_embed"] = jnp.full((b, cfg.vlm.n_vis_tokens, cfg.d_model),
                                      0.1, jnp.float32)
    if cfg.family == "encdec":
        batch["enc_embed"] = jnp.full((b, cfg.encdec.n_frames, cfg.d_model),
                                      0.1, jnp.float32)
    return batch


@pytest.mark.parametrize("family", list(FAMILY_CFGS))
def test_forward_loss_finite(family):
    cfg = FAMILY_CFGS[family]
    p = init_params(KEY, cfg, CTX)
    loss = lm_loss(p, make_batch(cfg), cfg, CTX, remat=False)
    assert jnp.isfinite(loss)
    # random-init loss should be near ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


@pytest.mark.parametrize("family", list(FAMILY_CFGS))
def test_grads_finite(family):
    cfg = FAMILY_CFGS[family]
    p = init_params(KEY, cfg, CTX)
    g = jax.grad(lambda p: lm_loss(p, make_batch(cfg), cfg, CTX,
                                   remat=True))(p)
    leaves = jax.tree.leaves(g)
    assert all(jnp.all(jnp.isfinite(x)) for x in leaves)
    # at least some gradient signal everywhere except possibly aux scalars
    nonzero = sum(float(jnp.abs(x).sum()) > 0 for x in leaves)
    assert nonzero >= len(leaves) - 2


@pytest.mark.parametrize("family", ["dense", "ssm", "hybrid", "moe"])
def test_decode_matches_forward(family):
    """Teacher-forced decode step-by-step == full forward logits.

    For MoE the capacity factor is raised so no token is dropped — capacity
    drops legitimately differ between a 1-token decode call and a full-
    sequence forward (different per-call capacities)."""
    cfg = FAMILY_CFGS[family]
    if family == "moe":
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_params(KEY, cfg, CTX)
    b, s = 2, 12
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    x, _ = forward(p, toks, cfg, CTX, remat=False)
    full_logits = lm_logits_local(p, x, cfg, CTX)   # [B,S,V]

    dcfg = DecodeConfig(cache_len_local=s, seq_shard=None)
    cache = init_cache(cfg, CTX, dcfg, b)
    outs = []
    for t in range(s):
        lg, cache = decode_step(p, cache, toks[:, t:t + 1],
                                jnp.int32(t), cfg, CTX, dcfg)
        outs.append(lg)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_swa_masks_long_range():
    """Sliding-window attention must ignore tokens beyond the window."""
    cfg = dense_cfg(sliding_window=4)
    p = init_params(KEY, cfg, CTX)
    s = 16
    t1 = jax.random.randint(KEY, (1, s), 0, cfg.vocab)
    # perturb a token far outside the window of the last position
    t2 = t1.at[0, 2].set((t1[0, 2] + 1) % cfg.vocab)
    x1, _ = forward(p, t1, cfg, CTX, remat=False)
    x2, _ = forward(p, t2, cfg, CTX, remat=False)
    # last position attends only to positions >= 12 (window 4, 2 layers can
    # reach back 2*window); position 2 is out of reach
    np.testing.assert_allclose(np.asarray(x1[0, -1]), np.asarray(x2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_causality():
    """Future tokens must not influence past logits."""
    cfg = dense_cfg()
    p = init_params(KEY, cfg, CTX)
    s = 10
    t1 = jax.random.randint(KEY, (1, s), 0, cfg.vocab)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
    x1, _ = forward(p, t1, cfg, CTX, remat=False)
    x2, _ = forward(p, t2, cfg, CTX, remat=False)
    np.testing.assert_allclose(np.asarray(x1[0, :-1]), np.asarray(x2[0, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_matches_dense():
    """Streaming softmax == plain softmax attention."""
    b, s, h, hd = 2, 50, 4, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, 2, hd))
    v = jax.random.normal(ks[2], (b, s, 2, hd))
    out = L.chunked_attention(q, k, v, causal=True, chunk=16)
    # dense reference
    import math
    kk = jnp.repeat(k, 2, axis=2)
    vv = jnp.repeat(v, 2, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(scores, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ssd_chunked_equals_naive_recurrence():
    from repro.models.ssm import _ssd_chunked
    b, s, h, hd, ds = 2, 37, 3, 8, 5
    ks = jax.random.split(KEY, 5)
    xh = jax.random.normal(ks[0], (b, s, h, hd))
    bt = jax.random.normal(ks[1], (b, s, ds)) * 0.5
    ct = jax.random.normal(ks[2], (b, s, ds)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.2)
    y, s_fin = _ssd_chunked(xh, bt, ct, dt, a, chunk=8)
    st_ = jnp.zeros((b, h, ds, hd))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a)
        st_ = st_ * da[:, :, None, None] + jnp.einsum(
            "bh,bs,bhd->bhsd", dt[:, t], bt[:, t], xh[:, t])
        ys.append(jnp.einsum("bs,bhsd->bhd", ct[:, t], st_))
    ref = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_fin), np.asarray(st_),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE dispatch properties
# ---------------------------------------------------------------------------

@given(t=st.integers(4, 64), e=st.sampled_from([2, 4, 8]),
       cap=st.integers(1, 16), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_property_dispatch_capacity(t, e, cap, seed):
    from repro.models.moe import dispatch_indices
    experts = jax.random.randint(jax.random.PRNGKey(seed), (t,), 0, e)
    slots, keep = dispatch_indices(experts, e, cap)
    slots = np.asarray(slots)
    keep = np.asarray(keep)
    # kept slots are unique and within their expert's capacity range
    kept = slots[keep]
    assert len(set(kept.tolist())) == len(kept)
    es = np.asarray(experts)[keep]
    assert ((kept >= es * cap) & (kept < (es + 1) * cap)).all()
    # per-expert kept count <= capacity
    for ee in range(e):
        assert (es == ee).sum() <= cap


def test_moe_combine_roundtrip():
    """dispatch -> identity expert -> combine reproduces kept tokens."""
    from repro.models.moe import (dispatch_indices, gather_to_buffers,
                                  combine_from_buffers)
    t, e, cap, d = 16, 4, 8, 8
    x = jax.random.normal(KEY, (t, d))
    experts = jax.random.randint(KEY, (t,), 0, e)
    slots, keep = dispatch_indices(experts, e, cap)
    buf = gather_to_buffers(x, slots, keep, e, cap)
    back = combine_from_buffers(buf, slots, keep, jnp.ones((t,)))
    got = np.asarray(back)
    want = np.where(np.asarray(keep)[:, None], np.asarray(x), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_vocab_parallel_xent_matches_dense():
    b, s, v = 2, 6, 32
    logits = jax.random.normal(KEY, (b, s, v))
    labels = jax.random.randint(KEY, (b, s), 0, v)
    nll = vocab_parallel_xent(logits, labels, CTX, v)
    ref = -jax.nn.log_softmax(logits)[
        jnp.arange(b)[:, None], jnp.arange(s)[None], labels]
    np.testing.assert_allclose(np.asarray(nll), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("arch", ["glm4_9b", "qwen2_72b", "starcoder2_15b",
                                  "whisper_medium", "mixtral_8x7b",
                                  "internvl2_76b", "kimi_k2_1t_a32b",
                                  "deepseek_67b", "zamba2_1p2b"])
@pytest.mark.parametrize("tp", [1, 2, 4, 8, 16])
def test_head_layout_covers_all_assigned_configs(arch, tp):
    """The unified GQA sharding must be consistent for every assigned arch
    at every TP degree up to the production mesh: local Q heads x shards ==
    global heads, and each shard's KV slice covers its Q heads' groups."""
    from repro.configs import get_config
    from repro.models.layers import head_layout
    from repro.models.tp import ParallelCtx
    cfg = get_config(arch)
    if cfg.n_heads % tp:
        pytest.skip("tp does not divide heads")
    ctx = ParallelCtx(tp_size=tp, tp_axis="model" if tp > 1 else None)
    hq_l, kv_w, group_l = head_layout(cfg, ctx)
    assert hq_l * tp == cfg.n_heads
    assert hq_l == kv_w * group_l
    # every shard's Q-head range maps into a contiguous KV range of width
    # kv_w starting at its first KV head
    group = cfg.n_heads // cfg.n_kv_heads
    for shard in range(tp):
        q_heads = range(shard * hq_l, (shard + 1) * hq_l)
        kv_needed = {h // group for h in q_heads}
        first = (shard * hq_l * cfg.n_kv_heads) // cfg.n_heads
        assert kv_needed == set(range(first, first + len(kv_needed)))
        assert len(kv_needed) <= kv_w


@given(sq=st.integers(1, 40), skv=st.integers(1, 70),
       chunk=st.sampled_from([4, 16, 64]),
       causal=st.booleans(), window=st.sampled_from([None, 3, 8]))
@settings(max_examples=20, deadline=None)
def test_property_chunked_attention_vs_dense(sq, skv, chunk, causal, window):
    """Streaming softmax == dense softmax for random shapes/chunking/masks
    (self-attention case: kv and q lengths equal when causal)."""
    import math
    if causal:
        skv = sq
    b, h, hkv, hd = 1, 2, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(sq * 1000 + skv), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, hkv, hd))
    v = jax.random.normal(ks[2], (b, skv, hkv, hd))
    out = L.chunked_attention(q, k, v, causal=causal, window=window,
                              chunk=chunk)
    kk = jnp.repeat(k, h // hkv, axis=2)
    vv = jnp.repeat(v, h // hkv, axis=2)
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(hd)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s_ = jnp.where(mask[None, None], s_, -jnp.inf)
    p_ = jax.nn.softmax(s_, axis=-1)
    p_ = jnp.where(jnp.isnan(p_), 0.0, p_)   # fully-masked rows
    ref = jnp.einsum("bhqk,bkhd->bqhd", p_, vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
