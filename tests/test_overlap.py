"""Overlapped bucketed gradient sync (DESIGN.md §11): GradBucketer
packing, bucketed-vs-monolithic bit-exactness, issue/await windows with
disjoint per-bucket Stage-2 multisets, the contention pricing model's
serial-case parity, and the overlap-aware roofline bounds.

Bit-exactness discipline (same as tests/test_cluster.py): reductions
associate differently per schedule, so parity tests drive them with
SMALL-INTEGER payloads — every partial sum is exactly representable in
fp32 AND bf16, making any summation order produce identical bits.
"""

from types import SimpleNamespace

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.communicator import (CommConfig, FlexCommunicator,
                                     comm_destroy_all, comm_init_rank)
from repro.core.links import PROFILES
from repro.core.simulator import PathTimingModel
from repro.core.topology import Collective
from repro.models.tp import ParallelCtx
from repro.roofline.analytic import step_time_bounds
from repro.runtime.program import StepProgram
from repro.train.bucketer import GradBucketer
from repro.train.train_step import sync_grads

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 CPU devices")

AR = Collective.ALL_REDUCE


@pytest.fixture(autouse=True)
def _fresh_comms():
    comm_destroy_all()
    yield
    comm_destroy_all()


def _mb(nbytes: int) -> float:
    return nbytes / 2.0 ** 20


# ---------------------------------------------------------------------------
# GradBucketer packing rules (pure metadata — no mesh needed)
# ---------------------------------------------------------------------------

def test_bucketer_splits_big_leaves_and_respects_target():
    grads = {"big": jnp.zeros((16, 32), jnp.float32),   # 2048 B, 128 B/row
             "small": jnp.zeros((4,), jnp.float32)}     # 16 B
    b = GradBucketer(grads, bucket_mb=_mb(512))
    total = sum(bk.nbytes for bk in b.buckets)
    assert total == 16 * 32 * 4 + 4 * 4
    # big splits into 4-row slabs; every bucket holds <= target unless a
    # single piece overflows (none does here)
    assert all(bk.nbytes <= 512 for bk in b.buckets)
    assert [bk.tag for bk in b.buckets] == \
        [f"g{i}" for i in range(len(b.buckets))]
    # reverse leaf order: the LAST leaf ("small") leads the issue order
    first = b.buckets[0].pieces[0]
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves[first.leaf].shape == (4,)
    # slabs of the split leaf are issued end-of-stack first
    slabs = [p.rows for bk in b.buckets for p in bk.pieces
             if p.rows is not None]
    assert slabs == sorted(slabs, reverse=True)


def test_bucketer_dtype_and_expert_homogeneity():
    grads = {"a": jnp.zeros((8, 8), jnp.float32),
             "moe": {"experts": {"w": jnp.zeros((8, 8), jnp.float32)}},
             "z": jnp.zeros((8, 8), jnp.bfloat16)}
    b = GradBucketer(grads, bucket_mb=1.0, ep=True)   # target >> leaves
    # three buckets despite the huge target: bf16 / expert / dense f32
    assert len(b.buckets) == 3
    kinds = {(bk.dtype, bk.expert) for bk in b.buckets}
    assert kinds == {("bfloat16", False), ("float32", True),
                     ("float32", False)}
    # without ep, experts merge with the dense f32 bucket
    b2 = GradBucketer(grads, bucket_mb=1.0, ep=False)
    assert len(b2.buckets) == 2


def test_bucketer_rejects_zero_and_roundtrips_without_comms():
    grads = {"w": jnp.arange(64, dtype=jnp.float32).reshape(16, 4),
             "b": jnp.arange(5, dtype=jnp.float32)}
    with pytest.raises(ValueError):
        GradBucketer(grads, bucket_mb=0.0)
    # no live communicators: every reduce no-ops, so sync must be the
    # slice/concat identity — bit-exact passthrough
    ctx = ParallelCtx()
    out = GradBucketer(grads, bucket_mb=_mb(64)).sync(grads, ctx)
    jax.tree.map(np.testing.assert_array_equal, out, grads)


# ---------------------------------------------------------------------------
# parity property test: bucketed == monolithic, bit-exact
# {fp32, bf16} x {1, 2}-node x ep_a2a on/off
# ---------------------------------------------------------------------------

def _parity_ctx(layout: str):
    if layout == "flat":
        mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
        ctx = ParallelCtx(dp_axis="data", dp_size=4,
                          comm_config=CommConfig(profile="tpu_v5e",
                                                 tag="ov-flat"))
        return mesh, ctx, P("data"), 4
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("node", "data"))
    ctx = ParallelCtx(dp_axis="data", node_axis="node",
                      dp_size=4, node_size=2,
                      comm_config=CommConfig(profile="tpu_v5e",
                                             tag="ov-node"))
    return mesh, ctx, P(("node", "data")), 8


def _int_grads(rng, world: int, ep: bool, dtype):
    g = {
        # big enough to split at the test's bucket target
        "deep": {"w": rng.integers(0, 8, size=(world * 24, 8))},
        "mid": rng.integers(0, 8, size=(world * 4, 3)),
        "tail": rng.integers(0, 8, size=(world, 2)),
    }
    if ep:
        g["moe"] = {"experts": {"wi": rng.integers(0, 8,
                                                   size=(world * 8, 5))}}
    return jax.tree.map(
        lambda a: jnp.asarray(a.astype(np.float32)).astype(dtype), g)


def _check_sync_parity(layout, dtype, ep, seed):
    comm_destroy_all()
    mesh, ctx, spec, world = _parity_ctx(layout)
    cfg = SimpleNamespace(moe=SimpleNamespace(impl="ep_a2a") if ep else None)
    grads = _int_grads(np.random.default_rng(seed), world, ep, dtype)

    def run(fn):
        f = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=spec,
                      check_vma=False)
        return jax.tree.map(np.asarray,
                            jax.tree.map(lambda a: a.astype(jnp.float32),
                                         jax.jit(f)(grads)))

    mono = run(lambda t: sync_grads(t, cfg, ctx))
    buck = run(lambda t: ctx.await_all(
        sync_grads(t, cfg, ctx, bucket_mb=_mb(256))))
    jax.tree.map(np.testing.assert_array_equal, buck, mono)


@needs8
@settings(max_examples=10, deadline=None)
@given(layout=st.sampled_from(["flat", "cluster"]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       ep=st.booleans(),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_bucketed_sync_bit_exact_vs_monolithic(layout, dtype, ep, seed):
    _check_sync_parity(layout, dtype, ep, seed)


@needs8
@pytest.mark.parametrize("layout,dtype,ep", [
    ("flat", "float32", False),
    ("flat", "bfloat16", True),
    ("cluster", "float32", True),
    ("cluster", "bfloat16", False),
])
def test_bucketed_sync_parity_fixed_grid(layout, dtype, ep):
    """Hypothesis-free anchor over the corners of the property grid, so
    the parity contract is enforced even where hypothesis is absent."""
    _check_sync_parity(layout, dtype, ep, seed=7)


# ---------------------------------------------------------------------------
# issue windows: disjoint per-bucket Stage-2 multisets + contention factor
# ---------------------------------------------------------------------------

def test_inflight_buckets_keep_disjoint_stage2_multisets():
    comm = comm_init_rank("x", 8, CommConfig(profile="h800"))
    comm.register_recorder("train")
    with comm.recording(comm.recorder("train"), name="train"):
        with comm.issue_scope("g0"):
            comm.plan_for(AR, jnp.zeros((512, 512), jnp.float32))
        with comm.issue_scope("g1"):
            comm.plan_for(AR, jnp.zeros((256, 256), jnp.float32))
    # base + two sub-recorders, each with exactly its own bucket's call
    assert len(comm.family_recorders("train")) == 3
    c0 = comm.recorder("train/g0").issued_calls()
    c1 = comm.recorder("train/g1").issued_calls()
    assert len(c0) == 1 and len(c1) == 1
    assert {n for _, n, _w in c0}.isdisjoint({n for _, n, _w in c1})
    assert not comm.recorder("train").issued_calls()
    # both buckets were in flight together: one shared window, pop 2
    (w0,), (w1,) = {w for *_, w in c0}, {w for *_, w in c1}
    assert w0 == w1
    assert comm.window_population(w0) == 2.0
    # the barrier closes the window: later issues get a FRESH one
    comm.await_barrier()
    with comm.recording(comm.recorder("train"), name="train"):
        with comm.issue_scope("g0"):
            comm.plan_for(AR, jnp.zeros((512, 512), jnp.float32))
    w2 = comm.recorder("train/g0").issued_calls()[-1][2]
    assert w2 != w0
    assert comm.window_population(w2) == 1.0
    # feeding Stage 2 the whole family does not blow up and prices each
    # call at its own window's population
    comm.observe_recorders(comm.family_recorders("train"))


def test_unregister_drops_issue_subrecorders():
    comm = comm_init_rank("x", 8, CommConfig(profile="h800"))
    comm.register_recorder("p")
    with comm.recording(comm.recorder("p"), name="p"):
        with comm.issue_scope("g0"):
            comm.plan_for(AR, jnp.zeros((64, 64), jnp.float32))
    assert "p/g0" in comm._recorders
    comm.unregister_recorder("p")
    assert "p/g0" not in comm._recorders and "p" not in comm._recorders


# ---------------------------------------------------------------------------
# contention pricing: serial case bitwise identical, k-way bounded
# ---------------------------------------------------------------------------

def test_contention_one_is_bitwise_identical():
    prof = PROFILES["h800"]
    shares = {prof.primary.name: 0.6}
    for link in prof.secondary:
        shares[link.name] = 0.4 / len(prof.secondary)
    a = PathTimingModel(prof).measure(AR, 8, 1 << 24, shares)
    b = PathTimingModel(prof).measure(AR, 8, 1 << 24, shares,
                                      contention=1.0)
    assert a == b                       # dict of floats, bitwise equality


def test_contention_scales_wire_time_not_latency():
    prof = PROFILES["h800"]
    shares = {prof.primary.name: 0.6}
    for link in prof.secondary:
        shares[link.name] = 0.4 / len(prof.secondary)
    t1 = PathTimingModel(prof).total_time(AR, 8, 1 << 26, shares)
    t2 = PathTimingModel(prof).total_time(AR, 8, 1 << 26, shares,
                                          contention=2.0)
    # halved bandwidth doubles the wire term but leaves latency alone
    assert t1 < t2 < 2.0 * t1


# ---------------------------------------------------------------------------
# StepProgram issue/await lifecycle
# ---------------------------------------------------------------------------

def _overlap_program(ctx, mesh, name):
    comm = ctx.comms()[0]

    def builder():
        def step(v):
            with ctx.issue("b0"):
                a = comm.all_reduce(v)
            with ctx.issue("b1"):
                b = comm.all_reduce(2.0 * v)
            return ctx.await_all(a + b)

        return jax.jit(shard_map(step, mesh=mesh, in_specs=(P("data"),),
                                 out_specs=P("data"), check_vma=False))

    x = (np.arange(4 * 8, dtype=np.float32) % 5).reshape(4 * 8, 1)
    return StepProgram(builder, ctx, name=name), jnp.asarray(x)


def test_step_program_issue_await_lifecycle():
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
    ctx = ParallelCtx(dp_axis="data", dp_size=4,
                      comm_config=CommConfig(profile="tpu_v5e",
                                             tag="ov-prog"))
    prog, x = _overlap_program(ctx, mesh, "ovl")
    try:
        h = prog.issue(x)
        assert not h.ready and prog._pending == [h]
        outs = prog.await_all()
        assert h.ready and len(outs) == 1 and not prog._pending
        want = 3.0 * np.asarray(x).reshape(4, 8, 1).sum(0)
        np.testing.assert_array_equal(
            np.asarray(outs[0]).reshape(4, 8, 1)[0], want)
        comm = ctx.comms()[0]
        # the traced issue scopes registered per-bucket sub-recorders
        # sharing one window of population 2
        c0 = comm.recorder("ovl/b0").issued_calls()
        c1 = comm.recorder("ovl/b1").issued_calls()
        assert len(c0) == 1 and len(c1) == 1
        assert c0[0][2] == c1[0][2]
        assert comm.window_population(c0[0][2]) == 2.0
        # second round: signature hit -> no re-trace, logs replay as-is
        prog.issue(x)
        outs2 = prog.await_all()
        np.testing.assert_array_equal(np.asarray(outs2[0]),
                                      np.asarray(outs[0]))
        assert prog.cache.report()["hits"] >= 1
        # an await with nothing pending is a harmless barrier
        assert prog.await_all() == []
    finally:
        prog.close()


# ---------------------------------------------------------------------------
# fused metrics reduce
# ---------------------------------------------------------------------------

@needs8
def test_metrics_reduce_matches_nested_psums():
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("node", "data"))
    ctx = ParallelCtx(dp_axis="data", node_axis="node",
                      dp_size=4, node_size=2,
                      comm_config=CommConfig(profile="tpu_v5e",
                                             tag="ov-metrics"))
    x = (np.arange(8 * 6, dtype=np.float32) % 7).reshape(8 * 6, 1)
    spec = P(("node", "data"))

    def fused(v):
        return ctx.metrics_reduce({"loss": v.sum()},
                                  {"lr": jnp.float32(0.5)})

    def nested(v):
        return {"loss": ctx.pod_psum(ctx.node_psum(ctx.dp_psum(v.sum()))),
                "lr": jnp.float32(0.5)}

    def run(fn):
        f = shard_map(fn, mesh=mesh, in_specs=(spec,), out_specs=P(),
                      check_vma=False)
        return jax.tree.map(np.asarray, jax.jit(f)(x))

    got, want = run(fused), run(nested)
    np.testing.assert_allclose(got["loss"], want["loss"], rtol=0, atol=0)
    assert got["lr"] == pytest.approx(0.5)


def test_metrics_reduce_passthrough_without_axes():
    ctx = ParallelCtx()
    out = ctx.metrics_reduce({"loss": jnp.float32(3.0)},
                             {"lr": jnp.float32(0.1)})
    assert float(out["loss"]) == 3.0 and float(out["lr"]) == \
        pytest.approx(0.1)


# ---------------------------------------------------------------------------
# overlap-aware roofline bounds
# ---------------------------------------------------------------------------

def test_step_time_bounds_bracket_and_degenerate():
    b1 = step_time_bounds(1.0, 0.5, 0.8, n_buckets=1)
    # monolithic: the two bounds coincide at the serial sum
    assert b1["t_step_overlap"] == b1["t_step_serial"] == 1.8
    b8 = step_time_bounds(1.0, 0.5, 0.8, n_buckets=8)
    assert b8["t_step_serial"] == b1["t_step_serial"]
    assert b8["t_step_overlap"] < b1["t_step_serial"]
    assert b8["t_step_overlap"] >= max(1.0, 0.8)
    assert b8["exposed_comm_s"] == pytest.approx(0.1)
    # comm-bound: overlap can never beat the collective term itself
    bc = step_time_bounds(0.1, 0.1, 1.0, n_buckets=4)
    assert bc["t_step_overlap"] >= 1.0
    # memory-bound side uses max(compute, memory)
    bm = step_time_bounds(0.2, 2.0, 0.5, n_buckets=4)
    assert bm["t_step_overlap"] == pytest.approx(
        max(2.0, 0.5 * 3 / 4) + 0.5 / 4)
