"""Double-buffered pipeline + monotonic-counter protocol tests (§3.1)."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.pipeline import (MonotonicPipe, StageTimes, N_BUFFERS,
                                 optimal_chunk_bytes, pipeline_time_s)


def test_in_order_delivery():
    pipe = MonotonicPipe()
    chunks = [np.full(4, i) for i in range(10)]
    got = []
    i = j = 0
    while j < len(chunks):
        if i < len(chunks) and pipe.try_produce(chunks[i]):
            i += 1
        out = pipe.try_consume()
        if out is not None:
            got.append(out)
            j += 1
    for want, have in zip(chunks, got):
        np.testing.assert_array_equal(want, have)


def test_producer_blocks_when_buffers_full():
    pipe = MonotonicPipe(n_buffers=2)
    assert pipe.try_produce(np.zeros(1))
    assert pipe.try_produce(np.ones(1))
    # both buffers full and unconsumed -> third produce must block
    assert not pipe.try_produce(np.full(1, 2.0))
    assert pipe.try_consume() is not None
    assert pipe.try_produce(np.full(1, 2.0))  # freed by the consume


def test_consumer_blocks_on_empty():
    pipe = MonotonicPipe()
    assert pipe.try_consume() is None


@given(schedule=st.lists(st.booleans(), min_size=1, max_size=200),
       n_buffers=st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_property_no_stale_reads_any_interleaving(schedule, n_buffers):
    """For ANY producer/consumer interleaving, every consumed chunk is the
    one produced for that iteration — the §3.1 strict-ordering claim."""
    pipe = MonotonicPipe(n_buffers=n_buffers)
    produced = 0
    consumed = 0
    for do_produce in schedule:
        if do_produce:
            if pipe.try_produce(np.full(2, produced)):
                produced += 1
        else:
            out = pipe.try_consume()
            if out is not None:
                assert out[0] == consumed, "stale or out-of-order read"
                consumed += 1
    # drain
    while consumed < produced:
        out = pipe.try_consume()
        assert out is not None
        assert out[0] == consumed
        consumed += 1


def test_overlap_beats_serial():
    """Double buffering approaches the slower-stage bound (§3.1)."""
    st_ = StageTimes(pd2h_GBps=26.0, h2cd_GBps=26.0, per_chunk_us=5.0)
    total = 256 * 2**20
    t2 = pipeline_time_s(total, 4 * 2**20, st_, n_buffers=2)
    t1 = pipeline_time_s(total, 4 * 2**20, st_, n_buffers=1)
    assert t2 < 0.6 * t1  # ~2x from overlapping the two stages
    # steady state bounded by the slower stage + one bubble
    slow_bound = total / (26.0e9)
    assert t2 >= slow_bound * 0.99


def test_4mb_buffer_choice():
    """§5.1: 'We empirically select a 4MB buffer' — the model's optimum
    matches for large transfers on H800-like stage speeds."""
    st_ = StageTimes(pd2h_GBps=26.0, h2cd_GBps=26.0, per_chunk_us=50.0)
    best = optimal_chunk_bytes(256 * 2**20, st_)
    assert best in (4 * 2**20, 8 * 2**20, 16 * 2**20)
    # and small chunks are measurably worse at high per-chunk overhead
    t_small = pipeline_time_s(256 * 2**20, 1 << 20, st_)
    t_best = pipeline_time_s(256 * 2**20, best, st_)
    assert t_best < t_small
