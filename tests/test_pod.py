"""repro.cluster pod tier (DESIGN.md §15): three-tier topology model,
pod-level hierarchical collectives, the rail-local ep_a2a dispatch, and
the pods=1 degeneration contract.

Same bit-exactness discipline as tests/test_cluster.py: reductions run
on SMALL-INTEGER payloads (every partial sum exact in fp32 AND bf16, so
any association is bit-identical); pure data movement (all_gather,
all_to_all) is bit-exact for arbitrary values.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.cluster import (ClusterTimingModel, make_cluster, pod_tier_name)
from repro.cluster.communicator import ClusterCommunicator
from repro.cluster.topology import degrade_cluster
from repro.core.communicator import (CommConfig, FlexCommunicator,
                                     bucket_for, comm_destroy_all)
from repro.core.links import PROFILES, LinkKind
from repro.core.simulator import MiB
from repro.core.topology import Collective

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 CPU devices")

AR, AG, RS, A2A = (Collective.ALL_REDUCE, Collective.ALL_GATHER,
                   Collective.REDUCE_SCATTER, Collective.ALL_TO_ALL)
EP_AXES = ("pod", "node", "data")


@pytest.fixture(autouse=True)
def _fresh_comms():
    comm_destroy_all()
    yield
    comm_destroy_all()


def _pod_cluster(pods, nodes):
    return make_cluster("h800", nodes, nics_per_node=4, nic_gbit=400.0,
                        pods=pods, pod_uplinks=4, pod_gbit=400.0)


def _comm3(p, n, m, tag):
    """One ClusterCommunicator over a (pod=p, node=n, data=m) mesh —
    tiers of size 1 are simply absent, like the launchers build them."""
    topo = _pod_cluster(p, n)
    intra = (FlexCommunicator("data", m,
                              CommConfig(profile="h800",
                                         tag=f"{tag}-intra"))
             if m > 1 else None)
    inter = (FlexCommunicator("node", n,
                              CommConfig(profile=topo.nic_tier.name,
                                         tag=f"{tag}-inter"),
                              ortho_name="data" if m > 1 else None)
             if n > 1 else None)
    pod = (FlexCommunicator("pod", p,
                            CommConfig(profile=topo.pod_tier.name,
                                       tag=f"{tag}-pod"),
                            ortho_name="node" if n > 1 else None)
           if p > 1 else None)
    return ClusterCommunicator(topo, intra, inter, pod)


def _mesh3(p, n, m):
    devs = np.asarray(jax.devices()[:p * n * m])
    return Mesh(devs.reshape(p, n, m), EP_AXES)


def _int_payload(shape, dtype, mod=7):
    return (np.arange(int(np.prod(shape))) % mod).reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# topology model: the pod tier is a registered NodeProfile like any other
# ---------------------------------------------------------------------------

def test_make_cluster_registers_deterministic_pod_tier():
    topo = _pod_cluster(2, 2)
    name = pod_tier_name("h800", 4, 400.0, 4.0)
    assert topo.n_pods == 2
    assert topo.pod_tier.name == name
    assert PROFILES[name] is topo.pod_tier
    assert topo.pod_tier.tier == "pod"
    assert topo.pod_tier.primary.kind is LinkKind.DCN_SPINE
    assert [m.name for m in topo.pod_tier.primary.members] == \
        [f"spine{i}" for i in range(4)]
    assert topo.tiers == ("intra", "inter", "pod")
    # re-building resolves to the SAME registered profile
    again = _pod_cluster(4, 2)
    assert again.pod_tier is topo.pod_tier


def test_oversubscription_divides_spine_bandwidth():
    lean = make_cluster("h800", 2, pods=2, pod_uplinks=4, pod_gbit=400.0,
                        oversubscription=1.0)
    fat = make_cluster("h800", 2, pods=2, pod_uplinks=4, pod_gbit=400.0,
                       oversubscription=4.0)
    assert lean.pod_tier.name != fat.pod_tier.name
    assert lean.pod_tier.primary.raw_GBps == pytest.approx(
        4.0 * fat.pod_tier.primary.raw_GBps)


def test_pods1_is_the_two_tier_topology_pinned():
    """The hard parity contract (DESIGN.md §15): pods=1 builds the exact
    2-tier object — same name, same tiers, NO pod profile — so every
    plan key, tuning entry and report of a pre-pod run is reproduced."""
    base = make_cluster("h800", 2, nics_per_node=4, nic_gbit=400.0)
    one = make_cluster("h800", 2, nics_per_node=4, nic_gbit=400.0, pods=1)
    assert one.pod_tier is None
    assert one.n_pods == 1
    assert one.name == base.name
    assert one.tiers == base.tiers == ("intra", "inter")
    assert one.nic_tier is base.nic_tier
    assert one == base


def test_degrade_cluster_routes_spine_faults_to_pod_tier():
    topo = _pod_cluster(2, 2)
    bad = degrade_cluster(topo, "spine:spine2=0.25")
    assert bad.name.endswith("!spine:spine2=0.25")
    assert bad.pod_tier.name != topo.pod_tier.name
    assert bad.nic_tier is topo.nic_tier          # NIC tier untouched
    # a rail fault still lands on the NIC tier, not the pod tier
    bad2 = degrade_cluster(topo, "rail:rail3=0.25")
    assert bad2.pod_tier is topo.pod_tier


# ---------------------------------------------------------------------------
# analytic model: three-tier time, rail-local a2a pricing
# ---------------------------------------------------------------------------

def test_three_tier_hierarchy_beats_flat_ring_for_large_messages():
    model = ClusterTimingModel(_pod_cluster(2, 2), 8)
    big = 256 * int(MiB)
    for op in (AR, AG):
        assert model.hierarchical_time(op, big) < model.flat_time(op, big)


def test_pods1_timing_is_the_two_tier_model():
    b = 1 << 24
    two = ClusterTimingModel(make_cluster("h800", 2), 8)
    one = ClusterTimingModel(make_cluster("h800", 2, pods=1), 8)
    for op in (AR, AG, RS):
        assert one.hierarchical_time(op, b) == two.hierarchical_time(op, b)
        assert one.flat_time(op, b) == two.flat_time(op, b)


def test_rail_local_a2a_beats_flat_and_naive_when_bandwidth_bound():
    model = ClusterTimingModel(_pod_cluster(4, 4), 8)
    big = 64 * int(MiB)
    rail = model.a2a_time(big, schedule="rail_local")
    assert rail < model.a2a_time(big, schedule="flat")
    assert rail < model.a2a_time(big, schedule="naive")
    with pytest.raises(ValueError):
        model.a2a_time(big, schedule="bogus")


# ---------------------------------------------------------------------------
# pods=1: the cluster comm path is byte-identical with the pod code present
# ---------------------------------------------------------------------------

@needs8
def test_pods1_cluster_comm_signature_parity_pinned():
    """Acceptance: a pods=1 ClusterCommunicator resolves the exact same
    quantized plans (pinned ``==`` on plan_signature()) and executes
    bit-identically to the 2-tier communicator — the pod tier is a
    strict superset, not a fork of the 2-tier path."""
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("node", "data"))

    def two_tier(tag, topo):
        intra = FlexCommunicator("data", 4, CommConfig(
            profile="h800", tag=f"{tag}-intra"))
        inter = FlexCommunicator("node", 2, CommConfig(
            profile=topo.nic_tier.name, tag=f"{tag}-inter"),
            ortho_name="data")
        return ClusterCommunicator(topo, intra, inter)

    cc_a = two_tier("par-a", make_cluster("h800", 2))
    cc_b = two_tier("par-b", make_cluster("h800", 2, pods=1))
    assert cc_b.pod is None and cc_b.comms() == (cc_b.intra, cc_b.inter)

    x = _int_payload((8 * 16, 3), np.float32)
    spec = P(("node", "data"))
    for fn_a, fn_b, out_spec in (
            (cc_a.all_reduce, cc_b.all_reduce, spec),
            (lambda v: cc_a.all_gather(v, tiled=True),
             lambda v: cc_b.all_gather(v, tiled=True), P()),
            (cc_a.reduce_scatter, cc_b.reduce_scatter, spec)):
        fa = shard_map(fn_a, mesh=mesh, in_specs=(spec,),
                       out_specs=out_spec, check_vma=False)
        fb = shard_map(fn_b, mesh=mesh, in_specs=(spec,),
                       out_specs=out_spec, check_vma=False)
        np.testing.assert_array_equal(np.asarray(jax.jit(fa)(x)),
                                      np.asarray(jax.jit(fb)(x)))
    assert cc_a.plan_signature() == cc_b.plan_signature()


# ---------------------------------------------------------------------------
# three-tier collectives: bit-exact vs the flat reference
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_three_tier_all_reduce_bit_exact_2x2x2(dtype):
    mesh = _mesh3(2, 2, 2)
    cc = _comm3(2, 2, 2, f"ar3-{dtype}")
    x = jnp.asarray(_int_payload((8 * 24, 5), np.float32)).astype(dtype)
    spec = P(EP_AXES)
    f = shard_map(cc.all_reduce, mesh=mesh, in_specs=(spec,),
                  out_specs=spec, check_vma=False)
    r = shard_map(lambda v: lax.psum(v, EP_AXES), mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(f)(x).astype(jnp.float32)),
        np.asarray(jax.jit(r)(x).astype(jnp.float32)))


@needs8
def test_three_tier_all_gather_outermost_major_order():
    mesh = _mesh3(2, 2, 2)
    cc = _comm3(2, 2, 2, "ag3-order")
    x = np.random.default_rng(0).normal(size=(8 * 6, 3)).astype(np.float32)
    spec = P(EP_AXES)
    f = shard_map(lambda v: cc.all_gather(v, tiled=True), mesh=mesh,
                  in_specs=(spec,), out_specs=P(), check_vma=False)
    r = shard_map(lambda v: lax.all_gather(v, EP_AXES, tiled=True),
                  mesh=mesh, in_specs=(spec,), out_specs=P(),
                  check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(jax.jit(r)(x)))


@needs8
def test_three_tier_reduce_scatter_segment_contract():
    """The documented shard-order contract one level up: rank
    (pod, node, i) holds global segment ``(i * n + node) * p + pod`` of
    the flat reduction (innermost-major interleaving)."""
    p, n, m = 2, 2, 2
    mesh = _mesh3(p, n, m)
    cc = _comm3(p, n, m, "rs3-order")
    x = _int_payload((8 * 8, 3), np.float32)
    spec = P(EP_AXES)

    def ref(v):
        red = lax.psum(v, EP_AXES)
        pod = lax.axis_index("pod")
        node = lax.axis_index("node")
        i = lax.axis_index("data")
        seg = red.shape[0] // (p * n * m)
        return lax.dynamic_slice_in_dim(
            red, ((i * n + node) * p + pod) * seg, seg, 0)

    f = shard_map(cc.reduce_scatter, mesh=mesh, in_specs=(spec,),
                  out_specs=spec, check_vma=False)
    r = shard_map(ref, mesh=mesh, in_specs=(spec,), out_specs=spec,
                  check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(jax.jit(r)(x)))


# ---------------------------------------------------------------------------
# property test: three-tier == flat across layouts and dtypes
# ---------------------------------------------------------------------------

#: (pods, nodes_per_pod, ranks_per_node) triples on the 8-device backend,
#: covering absent intra (m=1), absent inter (n=1) and all-live tiers.
_GRID3 = [(2, 2, 2), (2, 1, 4), (2, 4, 1), (4, 2, 1), (4, 1, 2)]


@needs8
@settings(max_examples=20, deadline=None)
@given(layout=st.sampled_from(_GRID3),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       cols=st.integers(min_value=1, max_value=5),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_three_tier_matches_flat_reference(layout, dtype, cols, seed):
    p, n, m = layout
    mesh = _mesh3(p, n, m)
    cc = _comm3(p, n, m, f"prop3-{p}x{n}x{m}")
    rng = np.random.default_rng(seed)
    rows = (p * n * m) * int(rng.integers(1, 4)) * 4
    x = rng.integers(0, 8, size=(rows, cols)).astype(np.float32)
    x = jnp.asarray(x).astype(dtype)
    spec = P(EP_AXES)

    fa = shard_map(cc.all_reduce, mesh=mesh, in_specs=(spec,),
                   out_specs=spec, check_vma=False)
    ra = shard_map(lambda v: lax.psum(v, EP_AXES), mesh=mesh,
                   in_specs=(spec,), out_specs=spec, check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fa)(x).astype(jnp.float32)),
        np.asarray(jax.jit(ra)(x).astype(jnp.float32)))

    fg = shard_map(lambda v: cc.all_gather(v, tiled=True), mesh=mesh,
                   in_specs=(spec,), out_specs=P(), check_vma=False)
    rg = shard_map(lambda v: lax.all_gather(v, EP_AXES, tiled=True),
                   mesh=mesh, in_specs=(spec,), out_specs=P(),
                   check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fg)(x).astype(jnp.float32)),
        np.asarray(jax.jit(rg)(x).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# rail-local ep_a2a: bit-exact vs the flat all_to_all
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_ep_a2a_bit_exact_vs_flat_all_to_all(dtype):
    """The MoE dispatch contract: the rail-local decomposition must
    equal the flat all_to_all over the combined (pod, node, data) axes
    bit for bit — a2a is pure data movement, so arbitrary values."""
    mesh = _mesh3(2, 2, 2)
    cc = _comm3(2, 2, 2, f"a2a3-{dtype}")
    x = np.random.default_rng(3).normal(size=(8 * 16, 3)).astype(np.float32)
    x = jnp.asarray(x).astype(dtype)
    spec = P(EP_AXES)
    f = shard_map(lambda v: cc.ep_all_to_all(v, 0, 0), mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    r = shard_map(lambda v: lax.all_to_all(v, EP_AXES, 0, 0, tiled=True),
                  mesh=mesh, in_specs=(spec,), out_specs=spec,
                  check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(f)(x).astype(jnp.float32)),
        np.asarray(jax.jit(r)(x).astype(jnp.float32)))


@needs8
def test_ep_a2a_two_tier_matches_flat_dp_all_to_all():
    """With no pod tier the same decomposition (intra shuffle + rail-
    aligned node leg) must still equal the flat dp-style all_to_all over
    (node, data) — the 2-tier degeneration of the dispatch."""
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("node", "data"))
    topo = make_cluster("h800", 2)
    intra = FlexCommunicator("data", 4, CommConfig(profile="h800",
                                                   tag="a2a2-intra"))
    inter = FlexCommunicator("node", 2, CommConfig(
        profile=topo.nic_tier.name, tag="a2a2-inter"), ortho_name="data")
    cc = ClusterCommunicator(topo, intra, inter)
    x = np.random.default_rng(5).normal(size=(8 * 8, 2)).astype(np.float32)
    spec = P(("node", "data"))
    f = shard_map(lambda v: cc.ep_all_to_all(v, 0, 0), mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    r = shard_map(
        lambda v: lax.all_to_all(v, ("node", "data"), 0, 0, tiled=True),
        mesh=mesh, in_specs=(spec,), out_specs=spec, check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(jax.jit(r)(x)))


@needs8
def test_ep_a2a_reports_rail_local_bytes():
    mesh = _mesh3(2, 2, 2)
    cc = _comm3(2, 2, 2, "a2a3-report")
    x = np.random.default_rng(7).normal(size=(8 * 16, 3)).astype(np.float32)
    spec = P(EP_AXES)
    f = shard_map(lambda v: cc.ep_all_to_all(v, 0, 0), mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    jax.block_until_ready(jax.jit(f)(x))
    rep = cc.a2a_report()
    assert rep["intra_bytes"] > 0
    assert rep["rail_local_bytes"] + rep["spine_bytes"] > 0
    s = cc.summary()
    assert set(s["rollup"]) == {"intra", "inter", "pod"}
    assert s["a2a"]["rail_local_bytes"] == rep["rail_local_bytes"]


# ---------------------------------------------------------------------------
# ctx integration: ep span over (pod, node, data), three-tier grad sync
# ---------------------------------------------------------------------------

@needs8
def test_ctx_pod_axis_three_tier_grad_reduce_and_ep_span():
    from repro.models.tp import ParallelCtx
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 2, 2, 1),
                ("pod", "node", "data", "model"))
    ctx = ParallelCtx(tp_axis="model", dp_axis="data", node_axis="node",
                      pod_axis="pod", tp_size=1, dp_size=2, node_size=2,
                      pod_size=2,
                      comm_config=CommConfig(profile="h800",
                                             tag="ctx-pod"))
    assert ctx._pod_comm is not None
    assert ctx.cluster.n_pods == 2
    assert ctx.ep_axes == EP_AXES and ctx.ep_size == 8
    assert ctx.ep_spec_axis() == EP_AXES
    assert [c.axis_name for c in ctx.comms()] == ["data", "node", "pod"]

    x = _int_payload((8 * 16, 3), np.float32)
    spec = P(EP_AXES)
    f = shard_map(lambda v: ctx.grad_all_reduce({"w": v})["w"], mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    r = shard_map(lambda v: lax.psum(v, EP_AXES), mesh=mesh,
                  in_specs=(spec,), out_specs=spec, check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(f)(x)),
                                  np.asarray(jax.jit(r)(x)))
    assert [s[0] for s in ctx.plan_signature()] == ["data", "node", "pod"]
    rep = ctx.comm_report()
    assert rep["pod"]["tier"] == "pod"
    roll = rep["cluster"]["rollup"]
    assert set(roll) == {"intra", "inter", "pod"}
    assert roll["pod"]["slots"] >= 1


# ---------------------------------------------------------------------------
# faults on the pod tier: spine events transition like any other tier
# ---------------------------------------------------------------------------

def test_spine_fault_transition_rekeys_pod_comm_warm(tmp_path):
    """A spine uplink fault commits one hysteresis-gated transition on
    the pod-tier communicator and re-keys it WARM from the degraded
    fabric's cached tune — PR 9's machinery, one tier up, for free."""
    from repro.faults import (FabricClock, HealthTimeline, HYSTERESIS_K,
                              parse_fault_schedule, validate_schedule)
    cluster = _pod_cluster(2, 2)
    tier = cluster.pod_tier
    degraded = degrade_cluster(cluster, "spine:spine2=0.25")
    cache = str(tmp_path / "tuning.json")
    payload = int(16 * MiB)

    for prof in (degraded.pod_tier.name, tier.name):
        c = FlexCommunicator("pod", 2, CommConfig(profile=prof,
                                                  tuning_cache=cache))
        for _ in range(12):
            c.record_call(AR, payload)
        c.save_tuning(cache)
    comm_destroy_all()

    tl = HealthTimeline(validate_schedule(
        parse_fault_schedule("spine:spine2@step10=0.25"),
        profiles=[cluster.nic_tier, tier], n_nodes=2))
    comm = FlexCommunicator("pod", 2, CommConfig(
        profile=tier.name, tuning_cache=cache, fault=tl.spec()))
    clock = FabricClock(tl, comms=lambda: [comm])
    committed = []
    for step in range(30):
        committed += clock.advance(step)
        comm.record_call(AR, payload)
    assert clock.rekeys == 1 and len(committed) == 1
    tr = committed[0]
    assert tr["step"] == 10 + HYSTERESIS_K - 1
    assert comm._effective_profile == degraded.pod_tier.name
    sc = comm.slot(AR, bucket_for(payload))
    assert sc.warm and sc.tuned.iterations == 0
    assert sc.origin == "transition:exact"


def test_resolve_faults_validates_spine_targets_against_pod_tier():
    from repro.configs.clusters import resolve_faults
    cluster = _pod_cluster(2, 2)
    # a spine target resolves only when the pod tier is in play
    _, _, tl = resolve_faults(cluster, 2, "h800",
                              fault="spine:spine2@step10=0.25", pods=2)
    assert tl is not None
    flat = make_cluster("h800", 2)
    with pytest.raises(ValueError, match="spine2"):
        resolve_faults(flat, 2, "h800",
                       fault="spine:spine2@step10=0.25")
