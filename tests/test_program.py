"""StepProgram runtime tests: the plan-keyed executable cache, per-program
Stage-2 replay recorders, and the acceptance behaviour of DESIGN.md §7 —
an oscillation A→B→A performs exactly 2 traces (2 rebuilds + a hit) while
the plan cache records the return to A as hit+retrace, and interleaved
programs on one memoized communicator keep disjoint replay logs without
``CommConfig.tag``."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.communicator import (CommConfig, FlexCommunicator,
                                     bucket_for, comm_destroy_all,
                                     comm_init_rank)
from repro.core.routing import PlanCache
from repro.core.topology import Collective
from repro.models.tp import ParallelCtx, single_device_ctx
from repro.runtime.exec_cache import ExecutableCache
from repro.runtime.program import StepProgram, program_scope

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 CPU devices")


@pytest.fixture(autouse=True)
def _fresh_comms():
    comm_destroy_all()
    yield
    comm_destroy_all()


def _mesh1d():
    return Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("x",))


def _tp_ctx():
    return ParallelCtx(tp_axis="x", tp_size=8,
                       comm_config=CommConfig(profile="h800"))


def _flip_shares(comm: FlexCommunicator, delta: int) -> None:
    """Move ``delta`` grid units between primary and the first secondary on
    every tuned balancer — a deterministic stand-in for a Stage-2 move big
    enough to change the quantized split (grid 100 → 16 chunk units)."""
    for bal in comm._balancers.values():
        sec = next(p for p in bal.shares if p != bal.primary)
        bal.shares[bal.primary] -= delta
        bal.shares[sec] += delta
        assert all(s >= 0 for s in bal.shares.values())


# ---------------------------------------------------------------------------
# ExecutableCache
# ---------------------------------------------------------------------------

def test_exec_cache_hit_rebuild_evict_counters():
    cache = ExecutableCache(capacity=2)
    assert cache.lookup("a", lambda: "exe-a") == "exe-a"
    assert cache.lookup("a", lambda: "never") == "exe-a"
    assert cache.stats.hits == 1 and cache.stats.rebuilds == 1
    cache.lookup("b", lambda: "exe-b")
    cache.lookup("c", lambda: "exe-c")        # evicts LRU entry "a"
    assert cache.stats.evictions == 1
    assert "a" not in cache and "b" in cache and "c" in cache
    rep = cache.report()
    assert rep == {"hits": 1, "rebuilds": 3, "evictions": 1, "size": 2,
                   "capacity": 2}


def test_exec_cache_lru_refresh_on_hit():
    cache = ExecutableCache(capacity=2)
    cache.lookup("a", lambda: 1)
    cache.lookup("b", lambda: 2)
    cache.get("a")                             # refresh "a" to MRU
    cache.lookup("c", lambda: 3)               # evicts "b", not "a"
    assert "a" in cache and "b" not in cache


def test_exec_cache_rejects_zero_capacity():
    with pytest.raises(ValueError):
        ExecutableCache(capacity=0)


# ---------------------------------------------------------------------------
# plan signatures
# ---------------------------------------------------------------------------

def test_plan_cache_signature_snapshots_slots():
    import repro.core.routing as rt
    cache = PlanCache()
    assert cache.plan_signature() == ()
    p = cache.lookup(Collective.ALL_REDUCE, 1 << 20,
                     lambda: rt.build_plan(Collective.ALL_REDUCE, "x",
                                           {"primary": 80, "staged": 20}))
    sig = cache.plan_signature()
    assert sig == (("all_reduce", 1 << 20, p),)
    assert cache.plan_signature() == sig       # stable without a move


def test_communicator_signature_tracks_share_moves():
    comm = FlexCommunicator("x", 8, CommConfig(profile="h800"))
    x = jnp.zeros((512, 512), jnp.float32)
    comm.plan_for(Collective.ALL_REDUCE, x)
    sig_a = comm.plan_signature()
    assert comm.plan_signature() == sig_a      # refresh is idempotent
    _flip_shares(comm, 20)                     # A -> B
    sig_b = comm.plan_signature()
    assert sig_b != sig_a
    _flip_shares(comm, -20)                    # move back
    retraces_before = comm.plan_cache.stats.retraces
    hits_before = comm.plan_cache.stats.hits
    assert comm.plan_signature() == sig_a
    # the return to a previously-seen plan is recorded as hit AND retrace
    assert comm.plan_cache.stats.retraces == retraces_before + 1
    assert comm.plan_cache.stats.hits > hits_before


# ---------------------------------------------------------------------------
# frozen CommConfig (satellite: the comm_init_rank memo key must not be
# mutable after construction)
# ---------------------------------------------------------------------------

def test_commconfig_is_frozen():
    cfg = CommConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.backend = "nccl"
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.tag = "oops"
    # replacement (not mutation) is the supported way to derive configs
    cfg2 = dataclasses.replace(cfg, backend="nccl")
    assert cfg2.backend == "nccl" and cfg.backend == "flexlink"


# ---------------------------------------------------------------------------
# per-program replay recorders (regression for the old KNOWN LIMIT: one
# shared per-communicator log, overwritten on interleaved traces)
# ---------------------------------------------------------------------------

def test_interleaved_recorders_keep_disjoint_multisets():
    comm = comm_init_rank("x", 8, CommConfig(profile="h800"))
    ra = comm.register_recorder("train")
    rb = comm.register_recorder("decode")
    x = jnp.zeros((512, 512), jnp.float32)
    y = jnp.zeros((256, 256), jnp.float32)

    def trace_train():                         # 3 identical + 1 distinct
        with comm.recording(ra):
            for _ in range(3):
                comm.plan_for(Collective.ALL_REDUCE, x)
            comm.plan_for(Collective.ALL_GATHER, x)

    def trace_decode():                        # 2 calls, smaller payload
        with comm.recording(rb):
            for _ in range(2):
                comm.plan_for(Collective.ALL_REDUCE, y)

    trace_train()
    trace_decode()                             # interleaved with train
    comm.observe_executed_step(ra)
    comm.observe_executed_step(rb)
    assert len(ra.issued_calls()) == 4         # multiplicity kept
    assert len(rb.issued_calls()) == 2         # NOT overwritten by train
    nb_a = {n for _, n, _w in ra.issued_calls()}
    nb_b = {n for _, n, _w in rb.issued_calls()}
    assert nb_a.isdisjoint(nb_b)               # disjoint logs, same comm
    assert comm.issued_calls() == []           # default recorder untouched
    trace_train()                              # Stage-2 re-trace of train
    comm.observe_executed_step(ra)
    assert len(ra.issued_calls()) == 4         # replaced, not appended
    assert len(rb.issued_calls()) == 2         # decode log untouched
    # steps without a re-trace keep replaying the promoted log
    comm.observe_executed_step(ra)
    assert len(ra.issued_calls()) == 4


def test_register_recorder_idempotent_and_unregister():
    comm = comm_init_rank("x", 8, CommConfig(profile="h800"))
    ra = comm.register_recorder("p")
    assert comm.register_recorder("p") is ra
    assert comm.recorder("p") is ra
    comm.unregister_recorder("p")
    with pytest.raises(KeyError):
        comm.recorder("p")
    comm.unregister_recorder("p")              # idempotent


def test_reset_issued_clears_program_recorders_too():
    comm = comm_init_rank("x", 8, CommConfig(profile="h800"))
    rec = comm.register_recorder("p")
    x = jnp.zeros((512, 512), jnp.float32)
    with comm.recording(rec):
        comm.plan_for(Collective.ALL_REDUCE, x)
    comm.plan_for(Collective.ALL_REDUCE, x)    # default recorder
    assert rec.issued_calls() and comm.issued_calls()
    comm.reset_issued()
    assert not rec.issued_calls() and not comm.issued_calls()


# ---------------------------------------------------------------------------
# StepProgram end-to-end (the acceptance scenario)
# ---------------------------------------------------------------------------

def _all_reduce_program(ctx, mesh, *, n_calls=1, rows=512, capacity=8,
                        name=""):
    """A tiny sharded step issuing ``n_calls`` tp all_reduces per trace,
    with a trace counter so re-jits are observable."""
    traces = []

    def builder():
        def step(v):
            traces.append(1)
            out = v
            for _ in range(n_calls):
                out = ctx.tp_all_reduce(out)
            return out
        return jax.jit(shard_map(step, mesh=mesh, in_specs=(P("x"),),
                                 out_specs=P("x"), check_vma=False))

    prog = StepProgram(builder, ctx, capacity=capacity, name=name)
    x = jnp.arange(8 * rows * 8, dtype=jnp.float32).reshape(8 * rows, 8)
    return prog, x, traces


@needs8
def test_oscillation_two_traces_one_hit():
    """A→B→A: exactly 2 traces; exec cache shows 2 rebuilds + >=1 hit; the
    plan cache still records the return to A as hit+retrace."""
    ctx = _tp_ctx()
    prog, x, traces = _all_reduce_program(ctx, _mesh1d())
    ref = np.asarray(x).reshape(8, -1, 8).sum(0)

    out = prog.step(x)                         # trace A
    np.testing.assert_allclose(np.asarray(out)[:x.shape[0] // 8], ref,
                               rtol=1e-5)
    comm = ctx.comms()[0]
    _flip_shares(comm, 20)                     # A -> B
    prog.step(x)                               # trace B
    assert len(traces) == 2
    retr_before = comm.plan_cache.stats.retraces
    hits_before = comm.plan_cache.stats.hits
    _flip_shares(comm, -20)                    # B -> back to A
    out = prog.step(x)                         # executable-cache hit
    np.testing.assert_allclose(np.asarray(out)[:x.shape[0] // 8], ref,
                               rtol=1e-5)
    assert len(traces) == 2                    # NO third trace
    rep = prog.cache.report()
    assert rep["rebuilds"] == 2 and rep["hits"] >= 1
    assert rep["evictions"] == 0
    # the plan cache recorded the oscillation back as hit+retrace
    assert comm.plan_cache.stats.retraces == retr_before + 1
    assert comm.plan_cache.stats.hits > hits_before


@needs8
def test_capacity_one_forces_rejit_on_each_flip():
    ctx = _tp_ctx()
    prog, x, traces = _all_reduce_program(ctx, _mesh1d(), capacity=1)
    prog.step(x)
    comm = ctx.comms()[0]
    _flip_shares(comm, 20)
    prog.step(x)
    _flip_shares(comm, -20)
    prog.step(x)                               # A evicted -> re-trace
    assert len(traces) == 3
    rep = prog.cache.report()
    assert rep["rebuilds"] == 3 and rep["evictions"] == 2


@needs8
def test_interleaved_programs_disjoint_replay_no_tag():
    """Two concurrently ticking programs on ONE axis and ONE CommConfig
    (no tag) keep isolated replay multisets with correct per-step
    multiplicity — the acceptance regression for the old shared log."""
    ctx = _tp_ctx()
    mesh = _mesh1d()
    prog_a, xa, _ = _all_reduce_program(ctx, mesh, n_calls=3, rows=512,
                                        name="train-like")
    prog_b, xb, _ = _all_reduce_program(ctx, mesh, n_calls=1, rows=256,
                                        name="decode-like")
    comm = ctx.comms()[0]
    assert len(ctx.comms()) == 1               # genuinely shared
    # interleave the two programs' ticks
    for _ in range(2):
        prog_a.step(xa)
        prog_b.step(xb)
    ra = comm.recorder(prog_a.name).issued_calls()
    rb = comm.recorder(prog_b.name).issued_calls()
    assert len(ra) == 3 and len(rb) == 1       # per-step multiplicity
    assert {n for _, n, _w in ra}.isdisjoint({n for _, n, _w in rb})
    # both programs report through the shared comm's report
    progs = comm.report()["programs"]
    assert progs[prog_a.name]["replay_len"] == 3
    assert progs[prog_b.name]["replay_len"] == 1


@needs8
def test_sibling_program_slots_do_not_rekey():
    """A program's executable-cache signature covers only the slots ITS
    traces touch: a sibling program tuning a new bucket — or oscillating a
    slot the first program never uses — on the SAME communicator must not
    force a spurious re-jit."""
    ctx = _tp_ctx()
    mesh = _mesh1d()
    prog_a, xa, traces_a = _all_reduce_program(ctx, mesh, rows=512,
                                               name="small-bucket")
    # rows chosen so the per-shard payload lands in a DIFFERENT bucket
    prog_b, xb, traces_b = _all_reduce_program(ctx, mesh, rows=49152,
                                               name="big-bucket")
    assert bucket_for(512 * 8 * 4) != bucket_for(49152 * 8 * 4)
    prog_a.step(xa)
    assert prog_a.cache.report()["rebuilds"] == 1
    prog_b.step(xb)                  # tunes a NEW slot on the shared comm
    comm = ctx.comms()[0]
    assert len(comm._balancers) == 2
    prog_a.step(xa)                  # foreign slot must not re-key a
    rep_a = prog_a.cache.report()
    assert rep_a["rebuilds"] == 1 and rep_a["hits"] == 1
    assert len(traces_a) == 1
    # oscillate ONLY b's slot: a stays cached, b re-keys
    bal = comm._balancers[(Collective.ALL_REDUCE, bucket_for(49152 * 8 * 4))]
    sec = next(p for p in bal.shares if p != bal.primary)
    assert bal.shares[bal.primary] >= 20
    bal.shares[bal.primary] -= 20
    bal.shares[sec] += 20
    prog_b.step(xb)
    prog_a.step(xa)
    assert len(traces_a) == 1
    assert prog_a.cache.report()["rebuilds"] == 1
    assert prog_b.cache.report()["rebuilds"] == 2 and len(traces_b) == 2


@needs8
def test_lower_does_not_pollute_replay_log():
    """Dry-run lowering traces the step but never executes it, so it must
    not leave pending calls that a later live execution would replay into
    Stage 2 (doubling the observed multiset)."""
    ctx = _tp_ctx()
    prog, x, traces = _all_reduce_program(ctx, _mesh1d())
    lowered = prog.lower(jax.ShapeDtypeStruct(x.shape, x.dtype))
    assert lowered is not None and len(traces) == 1
    comm = ctx.comms()[0]
    assert comm.recorder(prog.name).issued_calls() == []
    assert comm.issued_calls() == []           # default untouched too
    prog.step(x)                               # live trace + observe
    assert len(comm.recorder(prog.name).issued_calls()) == 1  # not 2
    # the scratch lower-recorder was unregistered again
    assert set(comm.report()["programs"]) == {prog.name}


@needs8
def test_program_scope_unregisters_on_exit():
    ctx = _tp_ctx()
    mesh = _mesh1d()

    def builder():
        return jax.jit(shard_map(lambda v: ctx.tp_all_reduce(v), mesh=mesh,
                                 in_specs=(P("x"),), out_specs=P("x"),
                                 check_vma=False))

    with program_scope(builder, ctx) as prog:
        prog(jnp.zeros((8 * 64, 8), jnp.float32))
        name = prog.name
        assert comm_init_rank("x", 8, CommConfig(profile="h800")) \
            .recorder(name) is not None
    with pytest.raises(KeyError):
        ctx.comms()[0].recorder(name)


# ---------------------------------------------------------------------------
# host loops through the runtime
# ---------------------------------------------------------------------------

def test_run_loop_drives_program_and_legacy_builder():
    from repro.train.loop import LoopConfig, run_loop
    ctx = single_device_ctx()

    def make_batches():
        while True:
            yield {}

    def builder():
        def step(params, opt_state, batch):
            return (params, opt_state,
                    {"loss": jnp.float32(1.0), "grad_norm": jnp.float32(0.0),
                     "lr": jnp.float32(1e-3)})
        return step

    loop = LoopConfig(total_steps=3, log_every=0)
    # legacy path: a zero-arg builder gets wrapped into a StepProgram
    _, _, hist = run_loop(builder, {}, {}, make_batches(), ctx, loop,
                          log=lambda s: None)
    assert hist == [1.0, 1.0, 1.0]
    # program path
    prog = StepProgram(builder, ctx)
    _, _, hist = run_loop(prog, {}, {}, make_batches(), ctx, loop,
                          log=lambda s: None)
    assert hist == [1.0, 1.0, 1.0]
    # a commless ctx has a constant signature: exactly one build ever
    assert prog.cache.report()["rebuilds"] == 1
    assert prog.cache.report()["hits"] == 2


def test_serve_engine_reports_executable_cache_stats():
    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serving.engine import ServeConfig, ServeEngine
    cfg = get_config("glm4-9b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, single_device_ctx(),
                      ServeConfig(slots=2, cache_len=48))
    eng.submit([5, 6, 7], max_new=4)
    eng.submit([9, 10, 11], max_new=4)
    eng.run_until_drained()
    assert len(eng.finished()) == 2
    rep = eng.comm_report()
    ec = rep["executable_cache"]
    assert ec["rebuilds"] == 1                 # single-device: one trace
    assert ec["hits"] >= 1                     # every later tick is a hit
    assert ec["evictions"] == 0
