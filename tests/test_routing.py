"""RoutePlan engine tests: plan construction/quantization, the PathExecutor
registry, the PlanCache, and end-to-end execute() losslessness on a mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import routing as rt
from repro.core.collectives import (CHUNK_GRID, PATH_ORDER, PATH_ORTHO,
                                    PATH_PRIMARY, PATH_STAGED)
from repro.core.communicator import CommConfig, FlexCommunicator, bucket_for
from repro.core.topology import Collective

needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 CPU devices")


def mesh2d():
    devs = np.asarray(jax.devices()[:8]).reshape(4, 2)
    return Mesh(devs, ("x", "y"))


# ---------------------------------------------------------------------------
# plan construction
# ---------------------------------------------------------------------------

def test_build_plan_quantizes_to_grain():
    plan = rt.build_plan(Collective.ALL_REDUCE, "x",
                         {"primary": 70, "staged": 20, "ortho": 10}, "y")
    units = plan.units()
    assert sum(units.values()) == CHUNK_GRID
    assert set(units) == {PATH_PRIMARY, PATH_STAGED, PATH_ORTHO}
    assert plan.paths == (PATH_PRIMARY, PATH_STAGED, PATH_ORTHO)


def test_build_plan_none_shares_is_primary_only():
    plan = rt.build_plan(Collective.ALL_GATHER, "x")
    assert plan.is_primary_only
    assert plan.units() == {PATH_PRIMARY: CHUNK_GRID}


def test_build_plan_drops_ortho_without_axis():
    plan = rt.build_plan(Collective.ALL_REDUCE, "x",
                         {"primary": 50, "staged": 25, "ortho": 25}, None)
    assert PATH_ORTHO not in plan.units()
    assert sum(plan.units().values()) == CHUNK_GRID


def test_plan_is_hashable_and_stable():
    mk = lambda: rt.build_plan(Collective.ALL_REDUCE, "x",
                               {"primary": 80, "staged": 20}, "y",
                               staged_substeps=3)
    a, b = mk(), mk()
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1


def test_all_to_all_folds_ortho_into_staged():
    """a2a has no ortho detour that avoids primary links: the ortho share
    must fold into the staged route at plan-build time."""
    plan = rt.build_plan(Collective.ALL_TO_ALL, "x",
                         {"primary": 50, "staged": 25, "ortho": 25}, "y")
    units = plan.units()
    assert PATH_ORTHO not in units
    ref = rt.build_plan(Collective.ALL_REDUCE, "x",
                        {"primary": 50, "staged": 25, "ortho": 25}, "y")
    folded = ref.units()
    assert units[PATH_STAGED] == (folded[PATH_STAGED] + folded[PATH_ORTHO])
    assert sum(units.values()) == CHUNK_GRID


def test_substeps_clamped():
    lo = rt.build_plan(Collective.ALL_REDUCE, "x", {"primary": 1},
                       staged_substeps=0)
    hi = rt.build_plan(Collective.ALL_REDUCE, "x", {"primary": 1},
                       staged_substeps=10_000)
    assert lo.staged_substeps == 1
    assert hi.staged_substeps == rt.MAX_STAGED_SUBSTEPS


# ---------------------------------------------------------------------------
# executor registry
# ---------------------------------------------------------------------------

def test_registry_covers_every_collective_path_cell():
    cells = {
        Collective.ALL_REDUCE: (PATH_PRIMARY, PATH_STAGED, PATH_ORTHO),
        Collective.ALL_GATHER: (PATH_PRIMARY, PATH_STAGED, PATH_ORTHO),
        Collective.REDUCE_SCATTER: (PATH_PRIMARY, PATH_STAGED, PATH_ORTHO),
        # a2a: ortho folds into staged at plan time, no ortho cell needed
        Collective.ALL_TO_ALL: (PATH_PRIMARY, PATH_STAGED),
    }
    for coll, paths in cells.items():
        for p in paths:
            assert callable(rt.executor_for(coll, p))


def test_unregistered_cell_raises():
    with pytest.raises(NotImplementedError):
        rt.executor_for(Collective.BROADCAST, PATH_STAGED)


def test_resolve_accumulate_policy():
    plan = rt.build_plan(Collective.ALL_REDUCE, "x",
                         {"primary": 50, "staged": 50})
    # sub-32-bit floats get the Pallas fp32 kernel closure
    assert rt.resolve_accumulate(plan, jnp.bfloat16) is not None
    assert rt.resolve_accumulate(plan, jnp.float16) is not None
    # f32: an fp32 accumulator is bitwise a + b — kernel is pure overhead
    assert rt.resolve_accumulate(plan, jnp.float32) is None
    # integers stay on native a + b (exact)
    assert rt.resolve_accumulate(plan, jnp.int32) is None
    # explicit override wins
    marker = lambda a, b: a
    assert rt.resolve_accumulate(plan, jnp.float32, marker) is marker
    nat = rt.build_plan(Collective.ALL_REDUCE, "x",
                        {"primary": 50, "staged": 50},
                        accumulate=rt.ACC_NATIVE)
    assert rt.resolve_accumulate(nat, jnp.float32) is None


# ---------------------------------------------------------------------------
# PlanCache
# ---------------------------------------------------------------------------

def test_plan_cache_hit_miss_retrace():
    cache = rt.PlanCache()
    build = lambda s: (lambda: rt.build_plan(Collective.ALL_REDUCE, "x", s))
    s1 = {"primary": 80, "staged": 20}
    s2 = {"primary": 50, "staged": 50}     # quantizes differently from s1
    a = cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(s1))
    assert cache.stats.misses == 1 and cache.stats.hits == 0
    b = cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(s1))
    assert b is a
    assert cache.stats.hits == 1
    # Stage-2 changed the quantized split -> same slot, new plan: a re-trace
    cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(s2))
    assert cache.stats.misses == 2 and cache.stats.retraces == 1
    # a different bucket is a fresh slot, not a re-trace
    cache.lookup(Collective.ALL_REDUCE, 2 << 20, build(s1))
    assert cache.stats.retraces == 1
    assert len(cache) == 3
    rep = cache.report()
    assert rep == {"hits": 1, "misses": 3, "retraces": 1, "size": 3}


def test_plan_cache_counts_retrace_on_return_to_previous_plan():
    """A slot oscillating A -> B -> A re-traces on EVERY flip, including
    the return to a previously-seen plan (which is a cache hit)."""
    cache = rt.PlanCache()
    build = lambda s: (lambda: rt.build_plan(Collective.ALL_REDUCE, "x", s))
    sA = {"primary": 80, "staged": 20}
    sB = {"primary": 50, "staged": 50}
    cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(sA))
    cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(sB))   # A -> B
    cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(sA))   # B -> A (hit)
    cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(sB))   # A -> B (hit)
    assert cache.stats.retraces == 3
    assert cache.stats.hits == 2 and cache.stats.misses == 2


def test_plan_cache_subquantum_share_move_is_a_hit():
    """A share move too small to change the quantized chunk_units is NOT a
    new jit variant — the cache must count a hit, not a miss/retrace."""
    cache = rt.PlanCache()
    build = lambda s: (lambda: rt.build_plan(Collective.ALL_REDUCE, "x", s))
    s1 = {"primary": 80, "staged": 20}
    s2 = {"primary": 79, "staged": 21}     # same 16-chunk split as s1
    p1 = rt.build_plan(Collective.ALL_REDUCE, "x", s1)
    p2 = rt.build_plan(Collective.ALL_REDUCE, "x", s2)
    assert p1.chunk_units == p2.chunk_units
    a = cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(s1))
    b = cache.lookup(Collective.ALL_REDUCE, 1 << 20, build(s2))
    assert b is a
    assert cache.stats.hits == 1 and cache.stats.retraces == 0


def test_communicator_plan_cache_hits_on_repeat_calls():
    comm = FlexCommunicator("x", 8, CommConfig(profile="h800"),
                            ortho_name="y")
    x = jnp.zeros((1024, 256), jnp.float32)
    p1 = comm.plan_for(Collective.ALL_REDUCE, x)
    p2 = comm.plan_for(Collective.ALL_REDUCE, x)
    assert p2 is p1
    stats = comm.plan_cache.stats
    assert stats.misses == 1 and stats.hits == 1
    rep = comm.report()["plan_cache"]
    assert rep["hits"] == 1 and rep["misses"] == 1


def test_communicator_retrace_counted_after_share_move():
    comm = FlexCommunicator("x", 8, CommConfig(profile="h800"),
                            ortho_name="y")
    # 256 MiB bucket: big enough that Stage 1 keeps secondaries active
    x = jnp.zeros((8192, 8192), jnp.float32)
    comm.plan_for(Collective.ALL_REDUCE, x)
    # force a move big enough to change the quantized split, then re-plan
    nbytes = x.size * x.dtype.itemsize
    bal = comm._balancers[(Collective.ALL_REDUCE, bucket_for(nbytes))]
    assert any(s > 0 for p, s in bal.shares.items() if p != bal.primary)
    moved_from = max((p for p in bal.shares if p != bal.primary),
                     key=lambda p: bal.shares[p])
    moved = min(20, bal.shares[moved_from])
    bal.shares[moved_from] -= moved
    bal.shares[bal.primary] += moved
    comm.plan_for(Collective.ALL_REDUCE, x)
    assert comm.plan_cache.stats.retraces == 1


def test_communicator_plan_pure_function_of_bucket():
    """Two different payload sizes in one bucket must get the SAME plan
    (same staged substeps) regardless of call order — the plan is a pure
    function of (op, bucket, shares)."""
    a = FlexCommunicator("x", 8, CommConfig(profile="h800"), ortho_name="y")
    b = FlexCommunicator("x", 8, CommConfig(profile="h800"), ortho_name="y")
    small = jnp.zeros((300, 1024), jnp.float32)      # ~1.2 MiB
    big = jnp.zeros((490, 1024), jnp.float32)        # ~1.9 MiB, same bucket
    assert bucket_for(small.size * 4) == bucket_for(big.size * 4)
    p_small_first = a.plan_for(Collective.ALL_REDUCE, small)
    p_big_after = a.plan_for(Collective.ALL_REDUCE, big)
    p_big_first = b.plan_for(Collective.ALL_REDUCE, big)
    assert p_small_first == p_big_after == p_big_first


def test_issued_log_replaced_not_doubled_by_retraces():
    """A fresh trace REPLACES the replay log: re-tracing one step between
    executed steps must not grow it, while per-step multiplicity of
    identical calls (e.g. one all_reduce per layer) is preserved."""
    comm = FlexCommunicator("x", 8, CommConfig(profile="h800"),
                            ortho_name="y")
    x = jnp.zeros((512, 512), jnp.float32)

    def trace_step():                     # 3 identical + 1 distinct call
        for _ in range(3):
            comm.plan_for(Collective.ALL_REDUCE, x)
        comm.plan_for(Collective.ALL_GATHER, x)

    trace_step()
    comm.observe_executed_step()          # promotes the trace log
    assert len(comm.issued_calls()) == 4  # multiplicity kept
    trace_step()                          # Stage-2 re-trace of the same step
    comm.observe_executed_step()
    assert len(comm.issued_calls()) == 4  # replaced, not appended
    comm.observe_executed_step()          # steps without re-trace replay it
    assert len(comm.issued_calls()) == 4


def test_nccl_backend_plans_are_primary_only_and_cached():
    comm = FlexCommunicator("x", 8, CommConfig(backend="nccl",
                                               profile="h800"))
    x = jnp.zeros((64, 64), jnp.float32)
    p = comm.plan_for(Collective.ALL_GATHER, x)
    assert p.is_primary_only
    comm.plan_for(Collective.ALL_GATHER, x)
    assert comm.plan_cache.stats.hits == 1


def test_staged_substeps_scale_with_payload():
    comm = FlexCommunicator("x", 8, CommConfig(profile="h800"))
    shares = {"primary": 60, "staged": 40}
    small = comm.staged_substeps_for(Collective.ALL_REDUCE, 1 << 20, shares)
    big = comm.staged_substeps_for(Collective.ALL_REDUCE, 1 << 30, shares)
    assert 1 <= small <= big <= rt.MAX_STAGED_SUBSTEPS
    assert big >= rt.DEFAULT_STAGED_SUBSTEPS
    none = comm.staged_substeps_for(Collective.ALL_REDUCE, 1 << 30,
                                    {"primary": 100})
    assert none == 1


# ---------------------------------------------------------------------------
# execute() end-to-end on a mesh
# ---------------------------------------------------------------------------

@needs8
@pytest.mark.parametrize("collective,ref", [
    (Collective.ALL_REDUCE, lambda v: lax.psum(v, "x")),
    (Collective.ALL_GATHER, lambda v: lax.all_gather(v, "x")),
])
def test_execute_matches_reference_payload_layout(collective, ref):
    mesh = mesh2d()
    plan = rt.build_plan(collective, "x",
                         {"primary": 50, "staged": 30, "ortho": 20}, "y",
                         staged_substeps=3)
    x = jnp.arange(4 * 6 * 5, dtype=jnp.float32).reshape(4 * 6, 5) * 0.37
    f = shard_map(lambda v: rt.execute(plan, v), mesh=mesh,
                  in_specs=(P("x"),), out_specs=P() if
                  collective is Collective.ALL_GATHER else P("x"),
                  check_vma=False)
    r = shard_map(ref, mesh=mesh, in_specs=(P("x"),),
                  out_specs=P() if collective is Collective.ALL_GATHER
                  else P("x"), check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x)),
                               np.asarray(jax.jit(r)(x)), rtol=2e-6)


@needs8
def test_execute_matches_reference_columns_layout():
    mesh = mesh2d()
    plan = rt.build_plan(Collective.REDUCE_SCATTER, "x",
                         {"primary": 50, "staged": 30, "ortho": 20}, "y",
                         staged_substeps=2)
    x = jnp.arange(4 * 8 * 3, dtype=jnp.float32).reshape(4 * 8, 3) * 0.25
    f = shard_map(lambda v: rt.execute(plan, v), mesh=mesh, in_specs=(P(),),
                  out_specs=P("x"), check_vma=False)
    r = shard_map(lambda v: lax.psum_scatter(v, "x", scatter_dimension=0,
                                             tiled=True),
                  mesh=mesh, in_specs=(P(),), out_specs=P("x"),
                  check_vma=False)
    np.testing.assert_allclose(np.asarray(jax.jit(f)(x)),
                               np.asarray(jax.jit(r)(x)), rtol=1e-6)


@needs8
def test_execute_all_to_all_with_folded_ortho():
    mesh = mesh2d()
    x = jnp.arange(4 * 8 * 5, dtype=jnp.float32).reshape(4 * 8, 5)
    got = shard_map(
        lambda v: rt.flex_all_to_all(v, "x", shares={"primary": 40,
                                                     "staged": 30,
                                                     "ortho": 30},
                                     ortho_name="y"),
        mesh=mesh, in_specs=(P("x"),), out_specs=P("x"), check_vma=False)
    ref = shard_map(lambda v: lax.all_to_all(v, "x", 0, 0, tiled=True),
                    mesh=mesh, in_specs=(P("x"),), out_specs=P("x"),
                    check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(got)(x)),
                                  np.asarray(jax.jit(ref)(x)))


@needs8
def test_pipelined_staged_ring_bit_exact_any_substeps():
    """Pure data movement: the chunk-pipelined all-gather ring is
    bit-identical for every pipeline depth."""
    from repro.core.collectives import ring_all_gather
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(8), ("x",))
    x = jnp.arange(8 * 13, dtype=jnp.float32) * 0.31
    outs = []
    for s in (1, 2, 3, 8):
        f = shard_map(lambda v, s=s: ring_all_gather(v, "x", substeps=s),
                      mesh=mesh, in_specs=(P("x"),), out_specs=P(),
                      check_vma=False)
        outs.append(np.asarray(jax.jit(f)(x)))
    for o in outs[1:]:
        np.testing.assert_array_equal(outs[0], o)


# ---------------------------------------------------------------------------
# review regressions
# ---------------------------------------------------------------------------

def test_resolve_accumulate_never_downcasts_wide_dtypes():
    """ACC_AUTO must not round float64/complex payloads through a float32
    accumulator (lossless contract)."""
    plan = rt.build_plan(Collective.ALL_REDUCE, "x",
                         {"primary": 50, "staged": 50})
    assert rt.resolve_accumulate(plan, jnp.float64) is None
    assert rt.resolve_accumulate(plan, jnp.complex64) is None
    assert rt.resolve_accumulate(plan, jnp.float16) is not None


def test_resolve_accumulate_explicit_kernel_policy():
    """ACC_KERNEL_FP32 is an explicit opt-in: forced for real floats (even
    f64 — the caller accepts fp32 rounding), rejected for dtypes the
    kernel cannot represent."""
    plan = rt.build_plan(Collective.ALL_REDUCE, "x",
                         {"primary": 50, "staged": 50},
                         accumulate=rt.ACC_KERNEL_FP32)
    assert rt.resolve_accumulate(plan, jnp.float64) is not None
    assert rt.resolve_accumulate(plan, jnp.float32) is not None
    with pytest.raises(TypeError):
        rt.resolve_accumulate(plan, jnp.int32)
    with pytest.raises(TypeError):
        rt.resolve_accumulate(plan, jnp.complex64)


def test_nccl_mode_does_not_grow_replay_log():
    comm = FlexCommunicator("x", 8, CommConfig(backend="nccl",
                                               profile="h800"))
    x = jnp.zeros((64, 64), jnp.float32)
    for _ in range(5):
        comm.plan_for(Collective.ALL_REDUCE, x)
    assert comm.issued_calls() == []


@needs8
def test_execute_rejects_indivisible_leading_dim():
    """Multi-path reduce_scatter must fail loudly (not return garbage) when
    the leading dim does not divide the axis size."""
    mesh = mesh2d()
    plan = rt.build_plan(Collective.REDUCE_SCATTER, "x",
                         {"primary": 50, "staged": 50})
    x = jnp.arange(6 * 2, dtype=jnp.float32).reshape(6, 2)
    f = shard_map(lambda v: rt.execute(plan, v), mesh=mesh, in_specs=(P(),),
                  out_specs=P("x"), check_vma=False)
    with pytest.raises(Exception):
        jax.jit(f)(x)


def test_config_tag_isolates_registry_entries():
    """Trace-only tooling (dry-run) must not share a communicator — and
    therefore a Stage-2 replay log — with a live workload."""
    from repro.core.communicator import comm_destroy_all, comm_init_rank
    comm_destroy_all()
    live = comm_init_rank("x", 8, CommConfig(profile="h800"))
    probe = comm_init_rank("x", 8, CommConfig(profile="h800", tag="dryrun"))
    assert live is not probe
    probe.plan_for(Collective.ALL_REDUCE, jnp.zeros((512, 512), jnp.float32))
    assert live.issued_calls() == []
    comm_destroy_all()


def test_ctx_reset_issued_clears_all_comms():
    from repro.core.communicator import comm_destroy_all
    from repro.models.tp import ParallelCtx
    comm_destroy_all()
    ctx = ParallelCtx(tp_axis="x", dp_axis="y", tp_size=4, dp_size=2,
                      comm_config=CommConfig(profile="h800"))
    x = jnp.zeros((512, 512), jnp.float32)
    for comm in ctx.comms():
        comm.plan_for(Collective.ALL_REDUCE, x)
        assert comm.issued_calls()
    ctx.reset_issued()
    assert all(not c.issued_calls() for c in ctx.comms())
    comm_destroy_all()
