"""Continuous batching + paged KV serving (DESIGN.md §13).

Covers the PR's correctness contract end to end: host-side block
accounting (allocator round-trip, table disjointness under out-of-order
retirement), the flash-decode kernel against its dense-gather oracle
({fp32,bf16} x GQA configs, fixed anchors + hypothesis), pad-row
zero-mass / zero-block invariants, preemption-by-eviction resume, and the
headline bit-identical greedy parity between the paged engine and the
wave engine — plus the batch-shape-bucket executable-cache warmth that
makes admission-driven shape changes re-jit-free.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs import get_config
from repro.core.communicator import comm_destroy_all
from repro.kernels import ops, ref
from repro.models import init_params, single_device_ctx
from repro.runtime.program import StepProgram
from repro.serving.engine import (PagedServeConfig, PagedServeEngine,
                                  ServeConfig, ServeEngine)
from repro.serving.paged_kv import BlockAllocator, NoFreeBlocks, PagedKVCache

KEY = jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _fresh():
    comm_destroy_all()
    yield
    comm_destroy_all()


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("glm4-9b").reduced()
    return cfg, init_params(KEY, cfg)


# ---------------------------------------------------------------------------
# host-side block accounting
# ---------------------------------------------------------------------------

def test_block_allocator_roundtrip_and_lifo_reuse():
    a = BlockAllocator(4)
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [0, 1, 2, 3]
    with pytest.raises(NoFreeBlocks):
        a.alloc()
    a.free(got[2])
    assert a.alloc() == got[2]          # most recently freed reused next
    rep = a.report()
    assert rep["allocs"] == 5 and rep["frees"] == 1
    assert rep["peak_in_use"] == 4 and rep["in_use"] == 4


def test_block_allocator_rejects_double_free():
    a = BlockAllocator(2)
    b = a.alloc()
    a.free(b)
    with pytest.raises(AssertionError):
        a.free(b)


def test_block_tables_disjoint_under_out_of_order_retirement():
    kv = PagedKVCache(8, 4, 4, 4)       # 8 blocks of 4 tokens, 4 rows

    def assert_disjoint():
        owned = [kv.blocks_of(r) for r in range(4)]
        flat = [b for blks in owned for b in blks]
        assert len(flat) == len(set(flat)), f"shared blocks: {owned}"
        assert all(0 <= b < 8 for b in flat)

    kv.ensure(0, 7)                     # 2 blocks
    kv.ensure(1, 5)                     # 2 blocks
    kv.ensure(2, 9)                     # 3 blocks
    assert_disjoint()
    assert kv.tokens_capacity(2) == 12 and kv.free_tokens == 4
    freed = kv.release(1)               # retire the MIDDLE row first
    assert freed == 2 and kv.n_blocks_of(1) == 0
    kv.ensure(3, 8)                     # reuses row 1's freed blocks
    assert_disjoint()
    # growing an existing row keeps its prefix blocks attached
    before = kv.blocks_of(0)
    kv.ensure(0, 8)
    assert kv.blocks_of(0)[: len(before)] == before
    with pytest.raises(NoFreeBlocks):
        kv.ensure(0, 16)                # pool dry -> scheduler's signal
    with pytest.raises(ValueError):
        kv.ensure(2, 17)                # over the per-request cap


# ---------------------------------------------------------------------------
# flash-decode kernel vs dense block-gather oracle
# ---------------------------------------------------------------------------

def _paged_case(seed, t_rows, hq, hkv, hd, nb, bs, maxb, dtype,
                n_pads=1):
    k1, k2, k3, k4, k5 = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(k1, (t_rows, hq, hd), jnp.float32).astype(dtype)
    kp = jax.random.normal(k2, (nb, bs, hkv, hd), jnp.float32).astype(dtype)
    vp = jax.random.normal(k3, (nb, bs, hkv, hd), jnp.float32).astype(dtype)
    tables = jax.random.randint(k4, (t_rows, maxb), 0, nb, jnp.int32)
    kv_valid = jax.random.randint(k5, (t_rows,), 1, maxb * bs + 1,
                                  jnp.int32)
    if n_pads:                          # bucket-padding rows: no KV at all
        kv_valid = kv_valid.at[-n_pads:].set(0)
    return q, kp, vp, tables, kv_valid


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 3e-5),
                                        (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (4, 1)])
def test_paged_flash_decode_matches_ref(dtype, atol, hq, hkv):
    q, kp, vp, tables, kv_valid = _paged_case(
        0, 6, hq, hkv, 64, nb=10, bs=8, maxb=3, dtype=dtype)
    got = ops.paged_flash_decode(q, kp, vp, tables, kv_valid)
    want = ref.paged_flash_decode_ref(q, kp, vp, tables, kv_valid)
    assert got.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), atol=atol)


def test_paged_flash_decode_sliding_window_matches_ref():
    q, kp, vp, tables, kv_valid = _paged_case(
        1, 5, 4, 2, 64, nb=12, bs=8, maxb=4, dtype=jnp.float32)
    got = ops.paged_flash_decode(q, kp, vp, tables, kv_valid, window=8)
    want = ref.paged_flash_decode_ref(q, kp, vp, tables, kv_valid,
                                      window=8)
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), atol=3e-5)
    # the window actually bites: full-context answer differs
    full = ref.paged_flash_decode_ref(q, kp, vp, tables, kv_valid)
    assert not np.allclose(np.asarray(want), np.asarray(full))


def test_pad_rows_contribute_exactly_zero():
    """Bucket-padding rows (kv_valid == 0) must emit EXACT zeros — the
    packed layout's 'pads cost zero attention mass' invariant, in both the
    kernel and the oracle."""
    q, kp, vp, tables, kv_valid = _paged_case(
        2, 6, 4, 2, 64, nb=10, bs=8, maxb=3, dtype=jnp.float32, n_pads=3)
    for fn in (ops.paged_flash_decode, ref.paged_flash_decode_ref):
        out = np.asarray(fn(q, kp, vp, tables, kv_valid))
        assert np.all(out[-3:] == 0.0), fn
        assert np.all(np.isfinite(out))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), t_rows=st.integers(1, 7),
       hkv=st.sampled_from([1, 2, 4]), bs=st.sampled_from([4, 8]),
       maxb=st.integers(1, 4),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_property_paged_flash_decode(seed, t_rows, hkv, bs, maxb, dtype):
    q, kp, vp, tables, kv_valid = _paged_case(
        seed, t_rows, 4, hkv, 64, nb=max(6, maxb + 2), bs=bs, maxb=maxb,
        dtype=dtype, n_pads=seed % t_rows if t_rows > 1 else 0)
    got = ops.paged_flash_decode(q, kp, vp, tables, kv_valid)
    want = ref.paged_flash_decode_ref(q, kp, vp, tables, kv_valid)
    atol = 3e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float64),
                               np.asarray(want, np.float64), atol=atol)


# ---------------------------------------------------------------------------
# engine parity — THE correctness contract
# ---------------------------------------------------------------------------

def _prompts(sizes, vocab=500, seed=3):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab, size=s).tolist() for s in sizes]


def test_paged_matches_wave_greedy_bit_identical(setup):
    """Same admitted set -> bit-identical greedy streams: the paged
    engine's packed prefill + block-gather attention reproduces the wave
    engine token for token, while its bucket ladder keeps every
    admission-driven shape change an exec-cache hit (one rebuild per
    bucket, never a re-jit)."""
    cfg, params = setup
    prompts = _prompts([5, 3, 9, 2, 7, 12])
    wave = ServeEngine(params, cfg, single_device_ctx(),
                       ServeConfig(slots=4, cache_len=96))
    for p in prompts:
        wave.submit(p, max_new=6)
    wave.run_until_drained()
    fw = wave.finished()
    wave.close()

    paged = PagedServeEngine(params, cfg, single_device_ctx(),
                             PagedServeConfig(max_requests=4, cache_len=96,
                                              kv_block=16,
                                              max_tokens_in_flight=16,
                                              min_bucket=4))
    for p in prompts:
        paged.submit(p, max_new=6)
    paged.run_until_drained()
    fp = paged.finished()
    rep = paged.serving_report()
    paged.close()

    assert fw == fp
    assert all(len(v) == 6 for v in fp.values())
    # batch-bucket exec-cache warmth: one rebuild per distinct bucket
    bc = rep["batch_bucket_cache"]
    assert bc["rebuilds"] == len(rep["buckets"])
    assert bc["hits"] > 0
    # packed prefill spends no KV on padding and balances its books
    kv = rep["kv_blocks"]
    assert kv["allocs"] == kv["frees"] and kv["in_use"] == 0


def test_preemption_resume_streams_unchanged(setup):
    """A block-starved pool forces preempt-by-eviction; teacher-forced
    re-prefill of prompt+out must resume every victim bit-identically, so
    the starved run's streams equal the uncontended run's."""
    cfg, params = setup
    prompts = _prompts([20, 18, 16, 22], seed=4)

    def run(n_blocks):
        eng = PagedServeEngine(params, cfg, single_device_ctx(),
                               PagedServeConfig(max_requests=4,
                                                cache_len=48, kv_block=8,
                                                n_blocks=n_blocks,
                                                max_tokens_in_flight=16,
                                                min_bucket=4))
        for p in prompts:
            eng.submit(p, max_new=12)
        eng.run_until_drained()
        fin, rep = eng.finished(), eng.serving_report()
        eng.close()
        return fin, rep

    fin_starved, rep_starved = run(n_blocks=9)   # < 4 requests' worth
    fin_ample, rep_ample = run(n_blocks=0)       # auto: no pressure
    assert rep_starved["scheduler"]["preemptions"] > 0
    assert rep_ample["scheduler"]["preemptions"] == 0
    assert fin_starved == fin_ample


def test_wave_coadmission_keeps_short_stream_unchanged(setup):
    """Wave right-alignment regression: a longer prompt co-admitted into
    the wave pads the short one's prefill, and those pad positions must
    carry zero attention mass — the short request's greedy stream cannot
    move."""
    cfg, params = setup
    short = _prompts([4], seed=5)[0]
    long = _prompts([11], seed=6)[0]

    def run(prompts):
        eng = ServeEngine(params, cfg, single_device_ctx(),
                          ServeConfig(slots=2, cache_len=48))
        rids = [eng.submit(p, max_new=6) for p in prompts]
        eng.run_until_drained()
        fin = eng.finished()
        eng.close()
        return [fin[r] for r in rids]

    alone = run([short])[0]
    together = run([short, long])[0]
    assert alone == together


def test_unallocated_pool_blocks_stay_zero(setup):
    """Pad rows and unadmitted capacity write NOTHING: pool blocks the
    allocator never handed out (it hands out ascending ids, so everything
    above peak_in_use is virgin) must still be exactly zero after a full
    serve."""
    cfg, params = setup
    eng = PagedServeEngine(params, cfg, single_device_ctx(),
                           PagedServeConfig(max_requests=2, cache_len=64,
                                            kv_block=8,
                                            max_tokens_in_flight=8,
                                            min_bucket=4))
    for p in _prompts([6, 9], seed=7):
        eng.submit(p, max_new=4)
    eng.run_until_drained()
    peak = eng.kv.report()["peak_in_use"]
    pool = eng.pool
    eng.close()
    assert 0 < peak < eng.pcfg.n_blocks
    for leaf in (pool["k"], pool["v"]):
        assert np.all(np.asarray(leaf[:, peak:]) == 0.0)
        assert np.any(np.asarray(leaf[:, :peak]) != 0.0)


# ---------------------------------------------------------------------------
# StepProgram batch-shape buckets
# ---------------------------------------------------------------------------

def test_step_program_shape_key_buckets():
    """Each shape_key keys its OWN executable: a revisited bucket is a
    cache hit, a new bucket a rebuild — and the report lists the buckets
    seen (the serve launcher's --assert-warm denominator)."""
    ctx = single_device_ctx()
    builds = []

    def builder():
        builds.append(1)
        return jax.jit(lambda x: x + 1.0)

    prog = StepProgram(builder, ctx)
    prog(jnp.zeros(4), shape_key=4)
    prog(jnp.zeros(8), shape_key=8)
    prog(jnp.zeros(4), shape_key=4)     # revisit: hit, no rebuild
    rep = prog.report()
    prog.close()
    assert len(builds) == 2
    assert rep["shape_buckets"] == [4, 8]
    assert rep["executable_cache"]["rebuilds"] == 2
    assert rep["executable_cache"]["hits"] == 1
