"""Timing-model tests: calibration fidelity + paper-claims reproduction.

The headline reproduction test lives here: Algorithm 1 run against the
simulator must land within tolerance of the paper's Table 2 improvements and
reproduce every qualitative claim (see DESIGN.md §6).
"""

import pytest

from repro.core.links import PROFILES, idle_bw_opportunity
from repro.core.simulator import (FLEXLINK_IMPROVEMENT_PCT,
                                  NCCL_BASELINE_GBPS, MiB, PathTimingModel)
from repro.core.topology import Collective, RingSchedule
from repro.core.tuner import initial_tune

PATHS = ["nvlink", "pcie", "rdma"]


def predict(op, n, mib, model=None):
    model = model or PathTimingModel("h800")
    payload = mib * MiB
    res = initial_tune(PATHS, "nvlink",
                       lambda fr: model.measure(op, n, payload, fr))
    flex = model.algbw_GBps(op, n, payload, res.fractions())
    nccl = model.nccl_baseline_GBps(op, n, payload)
    return nccl, flex, (flex / nccl - 1.0) * 100.0, res


def test_baseline_calibration_error_small():
    """Primary-path fit reproduces the NCCL baseline column to <6%."""
    model = PathTimingModel("h800")
    for (op, n, mib), gbps in NCCL_BASELINE_GBPS.items():
        pred = model.nccl_baseline_GBps(op, n, mib * MiB)
        assert abs(pred - gbps) / gbps < 0.06, (op, n, mib, pred, gbps)


def test_paper_improvements_within_tolerance():
    """Every Table-2 cell predicted within 10 percentage points."""
    for (op, n, mib), paper in FLEXLINK_IMPROVEMENT_PCT.items():
        _, _, impr, _ = predict(op, n, mib)
        assert abs(impr - paper) <= 10.0, (op, n, mib, impr, paper)


def test_headline_claims():
    """Abstract: AllReduce up to ~26%, AllGather up to ~27%."""
    ar = max(predict(Collective.ALL_REDUCE, n, m)[2]
             for (op, n, m) in FLEXLINK_IMPROVEMENT_PCT
             if op is Collective.ALL_REDUCE)
    ag = max(predict(Collective.ALL_GATHER, n, m)[2]
             for (op, n, m) in FLEXLINK_IMPROVEMENT_PCT
             if op is Collective.ALL_GATHER)
    assert 18.0 <= ar <= 34.0, ar
    assert 19.0 <= ag <= 35.0, ag


def test_offload_fraction_in_paper_range():
    """Abstract: 2-22%% of traffic offloaded to PCIe+RDMA."""
    for (op, n, mib) in FLEXLINK_IMPROVEMENT_PCT:
        *_, res = predict(op, n, mib)
        off = (res.shares["pcie"] + res.shares["rdma"]) / 100.0
        assert 0.0 <= off <= 0.30, (op, n, mib, off)


def test_8gpu_allreduce_latency_bound():
    """§5.3: 2(N-1)=14 steps amplify secondary latency -> near-zero gain."""
    _, _, impr, res = predict(Collective.ALL_REDUCE, 8, 256)
    assert impr <= 5.0
    assert res.shares["pcie"] + res.shares["rdma"] <= 5


def test_flexlink_never_below_baseline():
    """§5.4: 'at worst results in performance comparable to NCCL'."""
    for (op, n, mib) in FLEXLINK_IMPROVEMENT_PCT:
        nccl, flex, _, _ = predict(op, n, mib)
        assert flex >= nccl * 0.98


def test_pcie_contention_cap():
    """Table 1: contending paths are jointly capped by the PCIe interface."""
    model = PathTimingModel("h800")
    op, n, payload = Collective.ALL_GATHER, 8, 256 * MiB
    # force heavy shares onto both contending paths
    t = model.measure(op, n, payload, {"nvlink": 0.2, "pcie": 0.4, "rdma": 0.4})
    # effective joint bandwidth must not exceed the 64 GB/s switch ceiling
    sched = RingSchedule(op, n)
    wire_p = sched.wire_bytes(0.4 * payload)
    bw_p = wire_p / t["pcie"] / 1e9
    bw_r = sched.wire_bytes(0.4 * payload) / t["rdma"] / 1e9
    assert bw_p + bw_r <= 64.0 * 1.05


def test_idle_bw_opportunity_table1():
    """Table 1 'Idle BW Opportunity' column, recomputed from the DB."""
    expect = {"h800": 32, "h100": 14, "a800": 16, "gb200": 22, "gb300": 33}
    for name, pct in expect.items():
        got = idle_bw_opportunity(PROFILES[name]) * 100.0
        assert abs(got - pct) <= 3.0, (name, got, pct)


def test_tpu_profile_has_flexlink_headroom():
    """Our TPU v5e adaptation: secondary routes give a predicted gain for
    bandwidth-bound all_gather at large payloads."""
    model = PathTimingModel("tpu_v5e")
    paths = ["ici", "ici_ortho", "host_pcie", "dcn"]
    payload = 256 * MiB
    res = initial_tune(paths, "ici",
                       lambda fr: model.measure(
                           Collective.ALL_GATHER, 16, payload, fr))
    flex = model.algbw_GBps(Collective.ALL_GATHER, 16, payload,
                            res.fractions())
    nccl = model.nccl_baseline_GBps(Collective.ALL_GATHER, 16, payload)
    assert flex > nccl
