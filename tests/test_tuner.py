"""Algorithm 1 (Stage-1 coarse tuning) unit + property tests."""

import math

import pytest
from _hyp import given, settings, st

from repro.core.simulator import MiB, PathTimingModel
from repro.core.topology import Collective
from repro.core.tuner import (SHARE_GRID, initial_tune, initialize_shares)

PATHS = ["nvlink", "pcie", "rdma"]


def make_measure(op, n, mib, profile="h800", noise=0.0, seed=0):
    model = PathTimingModel(profile, noise=noise, seed=seed)
    payload = mib * MiB
    return lambda fr: model.measure(op, n, payload, fr)


def test_initial_shares_sum_to_grid():
    s = initialize_shares(PATHS, "nvlink")
    assert sum(s.values()) == SHARE_GRID
    assert s["nvlink"] >= max(s["pcie"], s["rdma"])  # primary dominant


def test_converges_on_allgather():
    res = initial_tune(PATHS, "nvlink",
                       make_measure(Collective.ALL_GATHER, 8, 256))
    assert res.converged
    assert sum(res.shares.values()) == SHARE_GRID
    # paper Table 2: 8-GPU AllGather offloads ~12+7 % — secondary paths live.
    assert res.shares["pcie"] > 0 and res.shares["rdma"] > 0
    assert 60 <= res.shares["nvlink"] <= 95


def test_8gpu_allreduce_backs_off_to_nvlink():
    """Paper §5.3: the scheduler correctly limits diversion for 8-GPU AR."""
    res = initial_tune(PATHS, "nvlink",
                       make_measure(Collective.ALL_REDUCE, 8, 256))
    assert res.shares["nvlink"] >= 95
    assert res.shares["pcie"] + res.shares["rdma"] <= 5


def test_damping_halves_step_on_bottleneck_shift():
    # Construct an oscillating oracle: whichever path holds more share is
    # "slow" — the bottleneck flips every move, so the step must halve.
    def measure(fracs):
        return {p: f for p, f in fracs.items()}  # time == share
    res = initial_tune(["nvlink", "pcie"], "nvlink", measure)
    steps = [t.step for t in res.trace if t.moved]
    assert any(b < a for a, b in zip(steps, steps[1:])), \
        "step never halved despite bottleneck flips"


def test_path_deactivation():
    # pcie is catastrophically slow -> its share must hit 0 and deactivate.
    def measure(fracs):
        out = {}
        for p, f in fracs.items():
            out[p] = f * (1000.0 if p == "pcie" else 1.0) + 1e-6
        return out
    res = initial_tune(["nvlink", "pcie"], "nvlink", measure)
    assert res.shares["pcie"] == 0
    assert "pcie" not in res.active
    assert res.converged  # NVLink-only exit (Alg.1 line 10)


def test_balanced_timings_at_convergence():
    model = PathTimingModel("h800")
    op, n, payload = Collective.ALL_GATHER, 4, 256 * MiB
    res = initial_tune(PATHS, "nvlink",
                       lambda fr: model.measure(op, n, payload, fr))
    if len(res.active) > 1:
        t = model.measure(op, n, payload, res.fractions())
        act = [t[p] for p in res.active]
        assert (max(act) - min(act)) / min(act) < 0.25


@given(mib=st.sampled_from([32, 64, 128, 256]),
       n=st.sampled_from([2, 4, 8]),
       op=st.sampled_from([Collective.ALL_GATHER, Collective.ALL_REDUCE,
                           Collective.REDUCE_SCATTER]))
@settings(max_examples=30, deadline=None)
def test_property_shares_invariants(mib, n, op):
    res = initial_tune(PATHS, "nvlink", make_measure(op, n, mib))
    assert sum(res.shares.values()) == SHARE_GRID
    assert all(v >= 0 for v in res.shares.values())
    assert res.iterations <= 100
    # the tuned config is never slower than NVLink-only (Alg.1 would have
    # deactivated the secondaries otherwise) — allow 2% simulator slack.
    model = PathTimingModel("h800")
    flex = model.algbw_GBps(op, n, mib * MiB, res.fractions())
    nccl = model.nccl_baseline_GBps(op, n, mib * MiB)
    assert flex >= nccl * 0.98


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_property_noise_robustness(seed):
    """Tuning under measurement noise still converges to sane shares."""
    res = initial_tune(
        PATHS, "nvlink",
        make_measure(Collective.ALL_GATHER, 8, 256, noise=0.05, seed=seed))
    assert sum(res.shares.values()) == SHARE_GRID
    assert res.shares["nvlink"] >= 50
